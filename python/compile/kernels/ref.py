"""Pure-jnp reference oracles for the Pallas kernels (layer 1).

Every kernel in this package has an exact (or tolerance-bounded) oracle
here; ``tests/test_kernels.py`` sweeps shapes/bit-widths with hypothesis and
asserts allclose. These functions are also the semantics the Rust
implementations in ``rust/src/gear/`` mirror.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_dequant_ref(x, bits: int, axis: int, group: int):
    """Group-wise asymmetric fake-quantization (Eq. 2 of the paper).

    x: [n, d]. axis=1: groups of `group` entries along each row (per-token);
    axis=0: groups along each column (per-channel). Returns the dequantized
    tensor (same shape).
    """
    n, d = x.shape
    levels = 2**bits - 1
    if axis == 1:
        g = min(group, d)
        pad = (-d) % g
        xp = jnp.pad(x, ((0, 0), (0, pad)))
        xg = xp.reshape(n, (d + pad) // g, g)
        mn = jnp.min(xg, axis=-1, keepdims=True)
        mx = jnp.max(xg, axis=-1, keepdims=True)
    else:
        g = min(group, n)
        pad = (-n) % g
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        xg = xp.reshape((n + pad) // g, g, d)
        mn = jnp.min(xg, axis=1, keepdims=True)
        mx = jnp.max(xg, axis=1, keepdims=True)
    delta = (mx - mn) / levels
    # Degenerate groups (constant) quantize to the zero-point exactly.
    safe = jnp.where(delta > 0, delta, 1.0)
    code = jnp.clip(jnp.round((xg - mn) / safe), 0, levels)
    deq = jnp.where(delta > 0, mn + code * delta, mn)
    if axis == 1:
        out = deq.reshape(n, d + pad)[:, :d]
    else:
        out = deq.reshape(n + pad, d)[:n, :]
    # Padding rows/cols contribute fake group extremes; recompute exactly for
    # the tail group when padding was needed (the Rust side has no padding).
    if pad:
        out = _quant_dequant_tail_exact(x, out, bits, axis, g)
    return out


def _quant_dequant_tail_exact(x, out, bits, axis, g):
    """Fix the final (ragged) group with an exact computation."""
    n, d = x.shape
    levels = 2**bits - 1
    if axis == 1:
        lo = (d // g) * g
        tail = x[:, lo:]
        mn = jnp.min(tail, axis=1, keepdims=True)
        mx = jnp.max(tail, axis=1, keepdims=True)
    else:
        lo = (n // g) * g
        tail = x[lo:, :]
        mn = jnp.min(tail, axis=0, keepdims=True)
        mx = jnp.max(tail, axis=0, keepdims=True)
    delta = (mx - mn) / levels
    safe = jnp.where(delta > 0, delta, 1.0)
    code = jnp.clip(jnp.round((tail - mn) / safe), 0, levels)
    deq = jnp.where(delta > 0, mn + code * delta, mn)
    if axis == 1:
        return out.at[:, lo:].set(deq)
    return out.at[lo:, :].set(deq)


def filter_outliers_ref(x, s: float, axis: int):
    """Per-vector top/bottom s/2 extraction (Eq. 4).

    Returns (sparse, remainder) with sparse + remainder == x. axis=0:
    per-channel vectors (Key); axis=1: per-token vectors (Value).
    """
    n, d = x.shape
    vec_len = n if axis == 0 else d
    k = int(round(vec_len * s / 2.0))
    if k == 0:
        return jnp.zeros_like(x), x
    xt = x.T if axis == 0 else x  # vectors along rows now
    top = jax.lax.top_k(xt, k)[1]
    bottom = jax.lax.top_k(-xt, k)[1]
    idx = jnp.concatenate([top, bottom], axis=1)
    mask_t = jnp.zeros_like(xt, dtype=bool)
    rows = jnp.arange(xt.shape[0])[:, None]
    mask_t = mask_t.at[rows, idx].set(True)
    mask = mask_t.T if axis == 0 else mask_t
    sparse = jnp.where(mask, x, 0.0)
    return sparse, x - sparse


def power_iter_ref(x, r: int, iters: int, seed: int = 0):
    """Power-iteration low-rank factorization (paper Algorithm 2).

    Returns (A [n, r], B [d, r]) with A @ B.T ~= top-r of x.
    """
    n, d = x.shape
    r = max(1, min(r, n, d))
    key = jax.random.PRNGKey(seed)
    b = jax.random.normal(key, (d, r), jnp.float32)
    a = jnp.zeros((n, r), jnp.float32)
    for l in range(max(1, iters)):
        last = l == max(1, iters) - 1
        if last:
            b, _ = jnp.linalg.qr(b)
        a = x @ b
        if last:
            a, _ = jnp.linalg.qr(a)
        b = x.T @ a
    return a, b


def headwise_lowrank_ref(x, n_heads: int, r: int, iters: int, seed: int = 0):
    """Head-wise low-rank approximation: reconstructed dense matrix."""
    n, d = x.shape
    assert d % n_heads == 0
    dh = d // n_heads
    parts = []
    for h in range(n_heads):
        sub = x[:, h * dh : (h + 1) * dh]
        a, b = power_iter_ref(sub, r, iters, seed + h)
        parts.append(a @ b.T)
    return jnp.concatenate(parts, axis=1)


def gear_ref(x, kind: str, bits: int, group: int, s: float, r: int, iters: int = 3):
    """Full GEAR pipeline on one matrix: returns the reconstruction.

    kind: "key" (per-channel axis) or "value" (per-token axis).
    """
    axis = 0 if kind == "key" else 1
    sparse, rem = filter_outliers_ref(x, s, axis)
    dq = quant_dequant_ref(rem, bits, axis, group)
    resid = rem - dq
    n_heads = 4 if x.shape[1] % 4 == 0 else 1
    low = headwise_lowrank_ref(resid, n_heads, r, iters) if r > 0 else 0.0
    return dq + low + sparse


def fused_attn_ref(q, k_deq, v_deq, n_heads: int):
    """Single-query multi-head attention over n cached tokens.

    q: [d]; k_deq/v_deq: [n, d] (already dequantized). Returns ctx [d].
    """
    n, d = k_deq.shape
    dh = d // n_heads
    qh = q.reshape(n_heads, dh)
    kh = k_deq.reshape(n, n_heads, dh)
    vh = v_deq.reshape(n, n_heads, dh)
    scores = jnp.einsum("hd,nhd->hn", qh, kh) / jnp.sqrt(jnp.float32(dh))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hn,nhd->hd", probs, vh).reshape(d)


def gear_attn_ref(q, codes, scales, zeros, a_k, b_k, v_deq, n_heads: int):
    """Oracle for the fused GEAR attention kernel: dequantize the 8-bit-ish
    integer codes (per-channel scales/zeros), add the head-wise low-rank
    correction, then attend.

    codes: [n, d] int32; scales/zeros: [d]; a_k: [H, n, r]; b_k: [H, dh, r].
    """
    n, d = codes.shape
    k_deq = zeros[None, :] + codes.astype(jnp.float32) * scales[None, :]
    h, _, r = a_k.shape
    dh = d // h
    low = jnp.einsum("hnr,hdr->nhd", a_k, b_k).reshape(n, d)
    return fused_attn_ref(q, k_deq + low, v_deq, n_heads)
