"""Pallas kernel: fused GEAR attention (dequant + low-rank + attend).

The paper's CUDA contribution fuses dequantization with the attention
matmul; this is the TPU-shaped analogue. One kernel invocation computes a
single decode-step query against a compressed K cache and a dense V tile:

    scores[t,h] = (q_h · (zeros + codes[t]·scales)_h
                   + (B_hᵀ q_h) · A_h[t]) / sqrt(d_H)
    ctx         = softmax_t(scores) @ V

The low-rank correction uses the factored form `(Bᵀq)·A[t]` — the paper's
"down-projection first" optimization — so the n×d low-rank matrix is never
materialized in VMEM.

VMEM budget (DESIGN.md §Hardware-Adaptation): codes int8 n×d + V f32 n×d +
factors ≈ 5·n·d bytes; at n=512, d=128 that is ~320 KiB — inside a TPU
core's ~16 MiB VMEM with room for double-buffering. `interpret=True` for
CPU execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gear_attn_kernel(q_ref, codes_ref, scales_ref, zeros_ref, a_ref, b_ref, v_ref, len_ref,
                      o_ref, *, n_heads: int):
    q = q_ref[...]                # [d]
    codes = codes_ref[...]        # [n, d] int8/int32
    scales = scales_ref[...]      # [d] (per-channel, KCVT Key layout)
    zeros = zeros_ref[...]        # [d]
    a = a_ref[...]                # [H, n, r]
    b = b_ref[...]                # [H, dh, r]
    v = v_ref[...]                # [n, d]
    cur_len = len_ref[0]          # int32: valid rows

    n, d = codes.shape
    dh = d // n_heads
    # Dequantize the K tile in registers/VMEM.
    k = zeros[None, :] + codes.astype(jnp.float32) * scales[None, :]
    kh = k.reshape(n, n_heads, dh)
    qh = q.reshape(n_heads, dh)
    scores = jnp.einsum("hd,nhd->hn", qh, kh)
    # Low-rank correction, factored: w_h = B_hᵀ q_h; scores += w_h · A_h[t].
    w = jnp.einsum("hdr,hd->hr", b, qh)
    scores = scores + jnp.einsum("hr,hnr->hn", w, a)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    valid = (jax.lax.iota(jnp.int32, n) < cur_len)[None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    vh = v.reshape(n, n_heads, dh)
    o_ref[...] = jnp.einsum("hn,nhd->hd", probs, vh).reshape(d)


@functools.partial(jax.jit, static_argnames=("n_heads",))
def gear_attn_pallas(q, codes, scales, zeros, a, b, v, cur_len, n_heads: int):
    """Fused GEAR decode attention.

    q: [d]; codes: [n, d] integer codes; scales/zeros: [d] per-channel
    quantization params; a: [H, n, r], b: [H, dh, r] low-rank K factors;
    v: [n, d] dense values; cur_len: int32 valid-row count. Returns [d].
    """
    n, d = codes.shape
    return pl.pallas_call(
        functools.partial(_gear_attn_kernel, n_heads=n_heads),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(q, codes, scales, zeros, a, b, v, jnp.asarray(cur_len, jnp.int32).reshape(1))
