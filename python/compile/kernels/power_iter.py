"""Pallas kernel: blocked matmuls for the power-iteration SVD solver.

Algorithm 2's cost is two skinny GEMMs per sweep (`A = X B`, `B = Xᵀ A`);
this module provides them as a tiled Pallas matmul (the MXU-shaped
hot-spot) and composes the full solver around jnp QR (QR runs once, on a
(n, r) panel — not a hot-spot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 64


def _matmul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] @ y_ref[...]


@jax.jit
def matmul_pallas(x, y):
    """Tiled `x @ y` (tiles the rows of x; y is small/skinny and stays
    resident — the power-iteration shape)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    pad_m = (-m) % BLOCK_M
    xp = jnp.pad(x, ((0, pad_m), (0, 0)))
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m + pad_m, n), jnp.float32),
        grid=((m + pad_m) // BLOCK_M,),
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, n), lambda i: (i, 0)),
        interpret=True,
    )(xp, y)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("r", "iters", "seed"))
def power_iter_pallas(x, r: int, iters: int, seed: int = 0):
    """Power-iteration low-rank factorization using the Pallas matmul.

    Returns (A [n, r], B [d, r]). Semantics match
    ``ref.power_iter_ref`` (same PRNG, same sweep structure).
    """
    n, d = x.shape
    r = max(1, min(r, n, d))
    iters = max(1, iters)
    key = jax.random.PRNGKey(seed)
    b = jax.random.normal(key, (d, r), jnp.float32)
    a = jnp.zeros((n, r), jnp.float32)
    xt = x.T
    for l in range(iters):
        last = l == iters - 1
        if last:
            b, _ = jnp.linalg.qr(b)
        a = matmul_pallas(x, b)
        if last:
            a, _ = jnp.linalg.qr(a)
        b = matmul_pallas(xt, a)
    return a, b
