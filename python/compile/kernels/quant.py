"""Pallas kernel: group-wise asymmetric fake-quantization (layer 1).

The compute hot-spot of the backbone `D̂ = Quant_b(X)` as a Pallas kernel.
The grid tiles the token axis; each program instance quantizes a
`(BLOCK_N, d)` tile held in VMEM.

Hardware adaptation (paper targets CUDA): the CUDA kernel fuses
dequantization into the attention GEMM over warps; on TPU the analogous
structure is a VMEM-resident tile dequantized right before the MXU matmul.
BlockSpec expresses the HBM→VMEM schedule the paper wrote with threadblocks.
`interpret=True` everywhere — the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 64


def _qdq_row_kernel(x_ref, o_ref, *, bits: int, group: int):
    """Quantize-dequantize one (BLOCK_N, d) tile with per-row groups."""
    x = x_ref[...]
    n, d = x.shape
    levels = 2**bits - 1
    g = min(group, d)
    # Whole tile is in VMEM; reshape to (n, n_groups, g). d % g == 0 is
    # enforced by the wrapper (ragged tails are handled there).
    xg = x.reshape(n, d // g, g)
    mn = jnp.min(xg, axis=-1, keepdims=True)
    mx = jnp.max(xg, axis=-1, keepdims=True)
    delta = (mx - mn) / levels
    safe = jnp.where(delta > 0, delta, 1.0)
    code = jnp.clip(jnp.round((xg - mn) / safe), 0, levels)
    deq = jnp.where(delta > 0, mn + code * delta, mn)
    o_ref[...] = deq.reshape(n, d)


@functools.partial(jax.jit, static_argnames=("bits", "axis", "group"))
def quant_dequant_pallas(x, bits: int, axis: int, group: int):
    """Group-wise fake-quantization via Pallas.

    x: [n, d] f32. axis=1: per-token groups of `group` along rows; axis=0:
    per-channel groups along columns (implemented by transposing around the
    row kernel — on TPU this would instead flip the BlockSpec index map).
    """
    if axis == 0:
        return quant_dequant_pallas(x.T, bits, 1, group).T
    n, d = x.shape
    g = min(group, d)
    main_d = (d // g) * g

    def run(xpart):
        nn, dd = xpart.shape
        pad_n = (-nn) % BLOCK_N
        xp = jnp.pad(xpart, ((0, pad_n), (0, 0)))
        grid = ((nn + pad_n) // BLOCK_N,)
        out = pl.pallas_call(
            functools.partial(_qdq_row_kernel, bits=bits, group=g),
            out_shape=jax.ShapeDtypeStruct(xp.shape, xp.dtype),
            grid=grid,
            in_specs=[pl.BlockSpec((BLOCK_N, dd), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((BLOCK_N, dd), lambda i: (i, 0)),
            interpret=True,
        )(xp)
        return out[:nn]

    if main_d == 0:
        # d < group: a single ragged group spanning the whole row.
        return run_single_group(x, bits)
    out_main = run(x[:, :main_d])
    if main_d == d:
        return out_main
    # Ragged tail group: quantized as its own (smaller) group.
    out_tail = run_single_group(x[:, main_d:], bits)
    return jnp.concatenate([out_main, out_tail], axis=1)


@functools.partial(jax.jit, static_argnames=("bits",))
def run_single_group(x, bits: int):
    """One group per row (whole-vector / KCVT grouping) via the same kernel."""
    n, d = x.shape
    pad_n = (-n) % BLOCK_N
    xp = jnp.pad(x, ((0, pad_n), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_qdq_row_kernel, bits=bits, group=d),
        out_shape=jax.ShapeDtypeStruct(xp.shape, xp.dtype),
        grid=((n + pad_n) // BLOCK_N,),
        in_specs=[pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
        interpret=True,
    )(xp)
    return out[:n]


def kcvt_pallas(x, bits: int, kind: str):
    """KCVT backbone: per-channel Key / per-token Value, whole-vector groups."""
    if kind == "key":
        return quant_dequant_pallas(x, bits, 0, x.shape[0])
    return quant_dequant_pallas(x, bits, 1, x.shape[1])
