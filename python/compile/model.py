"""Layer-2: the tiny-GPT model in JAX (build path only).

Architecture must match ``rust/src/model/transformer.rs`` exactly:
pre-LN decoder-only transformer, learned positional embeddings, GELU (tanh)
MLP, untied LM head, LayerNorm eps 1e-5 with biased variance. The golden
parity test (``tests/test_parity`` + rust ``tests/golden.rs``) enforces it.

The character vocabulary is shared verbatim with
``rust/src/model/config.rs``.
"""

from __future__ import annotations

import dataclasses
import struct

import jax
import jax.numpy as jnp
import numpy as np

# --- tokenizer (keep in lockstep with rust/src/model/config.rs) -------------

VOCAB_CHARS = "0123456789abcdefghijklmnopqrstuvwxyz=+-*%;?> \n"
PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3
VOCAB_SIZE = N_SPECIAL + len(VOCAB_CHARS)

_CHAR_TO_ID = {c: N_SPECIAL + i for i, c in enumerate(VOCAB_CHARS)}
_ID_TO_CHAR = {N_SPECIAL + i: c for i, c in enumerate(VOCAB_CHARS)}


def encode(text: str) -> list[int]:
    return [_CHAR_TO_ID[c] for c in text]


def encode_with_bos(text: str) -> list[int]:
    return [BOS] + encode(text)


def decode_ids(ids) -> str:
    return "".join(_ID_TO_CHAR.get(int(i), "") for i in ids)


# --- config ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    max_seq: int = 640

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def mlp_dim(self) -> int:
        return 4 * self.d_model


# --- parameters ---------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Initialize parameters (scaled-normal init)."""
    rng = np.random.default_rng(seed)
    s = 0.02

    def normal(*shape):
        return jnp.asarray(rng.normal(0.0, s, size=shape), dtype=jnp.float32)

    params = {
        "emb": normal(cfg.vocab, cfg.d_model),
        "pos": normal(cfg.max_seq, cfg.d_model),
        "head": normal(cfg.d_model, cfg.vocab),
        "ln_f.g": jnp.ones(cfg.d_model, jnp.float32),
        "ln_f.b": jnp.zeros(cfg.d_model, jnp.float32),
        "blocks": [],
    }
    for _ in range(cfg.n_layers):
        params["blocks"].append(
            {
                "ln1.g": jnp.ones(cfg.d_model, jnp.float32),
                "ln1.b": jnp.zeros(cfg.d_model, jnp.float32),
                "wq": normal(cfg.d_model, cfg.d_model),
                "wk": normal(cfg.d_model, cfg.d_model),
                "wv": normal(cfg.d_model, cfg.d_model),
                "wo": normal(cfg.d_model, cfg.d_model),
                "ln2.g": jnp.ones(cfg.d_model, jnp.float32),
                "ln2.b": jnp.zeros(cfg.d_model, jnp.float32),
                "w1": normal(cfg.d_model, cfg.mlp_dim),
                "b1": jnp.zeros(cfg.mlp_dim, jnp.float32),
                "w2": normal(cfg.mlp_dim, cfg.d_model),
                "b2": jnp.zeros(cfg.d_model, jnp.float32),
            }
        )
    return params


# --- forward -------------------------------------------------------------------


def _layernorm(x, g, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, blk, h, mask):
    """Dense causal multi-head attention. h: [B, T, d]; mask: [T, T] bool."""
    b, t, d = h.shape
    nh, dh = cfg.n_heads, cfg.head_dim
    q = (h @ blk["wq"]).reshape(b, t, nh, dh)
    k = (h @ blk["wk"]).reshape(b, t, nh, dh)
    v = (h @ blk["wv"]).reshape(b, t, nh, dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, d)
    return ctx @ blk["wo"], k.reshape(b, t, d), v.reshape(b, t, d)


def forward(params, cfg: ModelConfig, tokens):
    """Full forward: tokens [B, T] int32 -> logits [B, T, vocab]."""
    b, t = tokens.shape
    x = params["emb"][tokens] + params["pos"][:t][None, :, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for blk in params["blocks"]:
        h = _layernorm(x, blk["ln1.g"], blk["ln1.b"])
        attn, _, _ = _attention(cfg, blk, h, mask)
        x = x + attn
        h = _layernorm(x, blk["ln2.g"], blk["ln2.b"])
        x = x + jax.nn.gelu(h @ blk["w1"] + blk["b1"], approximate=True) @ blk["w2"] + blk["b2"]
    x = _layernorm(x, params["ln_f.g"], params["ln_f.b"])
    return x @ params["head"]


def prefill_graph(params, cfg: ModelConfig, tokens):
    """AOT prefill: tokens [1, T] -> (last_logits [vocab], K [L,T,d], V [L,T,d]).

    Mirrors the rust engine's prefill: exact dense attention, K/V exported
    for the cache.
    """
    b, t = tokens.shape
    assert b == 1
    x = params["emb"][tokens] + params["pos"][:t][None, :, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    ks, vs = [], []
    for blk in params["blocks"]:
        h = _layernorm(x, blk["ln1.g"], blk["ln1.b"])
        attn, k, v = _attention(cfg, blk, h, mask)
        ks.append(k[0])
        vs.append(v[0])
        x = x + attn
        h = _layernorm(x, blk["ln2.g"], blk["ln2.b"])
        x = x + jax.nn.gelu(h @ blk["w1"] + blk["b1"], approximate=True) @ blk["w2"] + blk["b2"]
    x = _layernorm(x, params["ln_f.g"], params["ln_f.b"])
    logits = (x @ params["head"])[0, -1]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_graph(params, cfg: ModelConfig, token, pos, k_cache, v_cache, cur_len):
    """AOT decode step with a dense KV cache of bucket size N.

    token: int32 scalar; pos: int32 scalar; k_cache/v_cache: [L, N, d]
    (rows >= cur_len are garbage and masked); cur_len: int32 scalar =
    tokens already cached (the new token attends to cur_len + 1 rows).

    Returns (logits [vocab], new_k [L, d], new_v [L, d]). The caller writes
    new_k/new_v into row cur_len of its cache.
    """
    d, nh, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    n = k_cache.shape[1]
    x = params["emb"][token] + params["pos"][pos]
    new_ks, new_vs = [], []
    for li, blk in enumerate(params["blocks"]):
        h = _layernorm(x, blk["ln1.g"], blk["ln1.b"])
        q = h @ blk["wq"]
        k_new = h @ blk["wk"]
        v_new = h @ blk["wv"]
        new_ks.append(k_new)
        new_vs.append(v_new)
        # Attend over cached rows + the new token's row.
        k_all = jax.lax.dynamic_update_slice(k_cache[li], k_new[None, :], (cur_len, 0))
        v_all = jax.lax.dynamic_update_slice(v_cache[li], v_new[None, :], (cur_len, 0))
        kh = k_all.reshape(n, nh, dh)
        vh = v_all.reshape(n, nh, dh)
        qh = q.reshape(nh, dh)
        scores = jnp.einsum("hd,nhd->hn", qh, kh) / jnp.sqrt(jnp.float32(dh))
        valid = (jnp.arange(n) <= cur_len)[None, :]
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("hn,nhd->hd", probs, vh).reshape(d)
        x = x + ctx @ blk["wo"]
        h = _layernorm(x, blk["ln2.g"], blk["ln2.b"])
        x = x + jax.nn.gelu(h @ blk["w1"] + blk["b1"], approximate=True) @ blk["w2"] + blk["b2"]
    x = _layernorm(x, params["ln_f.g"], params["ln_f.b"])
    return x @ params["head"], jnp.stack(new_ks), jnp.stack(new_vs)


# --- checkpoint I/O (GSRV format, see rust/src/model/weights.rs) ---------------

MAGIC = b"GSRV"
VERSION = 1


def flatten_params(params, cfg: ModelConfig) -> list[tuple[str, np.ndarray]]:
    out = [
        ("emb", params["emb"]),
        ("pos", params["pos"]),
        ("head", params["head"]),
        ("n_heads", np.array([cfg.n_heads], np.float32)),
        ("ln_f.g", params["ln_f.g"]),
        ("ln_f.b", params["ln_f.b"]),
    ]
    for i, blk in enumerate(params["blocks"]):
        for name in [
            "ln1.g", "ln1.b", "ln2.g", "ln2.b", "b1", "b2",
        ]:
            out.append((f"blocks.{i}.{'mlp.' if name in ('b1', 'b2') else ''}{name}", blk[name]))
        for name in ["wq", "wk", "wv", "wo"]:
            out.append((f"blocks.{i}.attn.{name}", blk[name]))
        out.append((f"blocks.{i}.mlp.w1", blk["w1"]))
        out.append((f"blocks.{i}.mlp.w2", blk["w2"]))
    return [(n, np.asarray(t, np.float32)) for n, t in out]


def save_checkpoint(path: str, params, cfg: ModelConfig) -> None:
    tensors = flatten_params(params, cfg)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors:
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.astype("<f4").tobytes())


def load_checkpoint(path: str) -> tuple[dict, ModelConfig]:
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == MAGIC, "bad magic"
    version, count = struct.unpack_from("<II", data, 4)
    assert version == VERSION
    off = 12
    tensors = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + nlen].decode()
        off += nlen
        (ndim,) = struct.unpack_from("<I", data, off)
        off += 4
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, "<f4", count=n, offset=off).reshape(dims)
        off += 4 * n
        tensors[name] = jnp.asarray(arr)

    vocab, d_model = tensors["emb"].shape
    max_seq = tensors["pos"].shape[0]
    n_heads = int(tensors["n_heads"][0])
    n_layers = 0
    while f"blocks.{n_layers}.attn.wq" in tensors:
        n_layers += 1
    cfg = ModelConfig(vocab, d_model, n_layers, n_heads, max_seq)
    params = {
        "emb": tensors["emb"],
        "pos": tensors["pos"],
        "head": tensors["head"],
        "ln_f.g": tensors["ln_f.g"],
        "ln_f.b": tensors["ln_f.b"],
        "blocks": [],
    }
    for i in range(n_layers):
        params["blocks"].append(
            {
                "ln1.g": tensors[f"blocks.{i}.ln1.g"],
                "ln1.b": tensors[f"blocks.{i}.ln1.b"],
                "wq": tensors[f"blocks.{i}.attn.wq"],
                "wk": tensors[f"blocks.{i}.attn.wk"],
                "wv": tensors[f"blocks.{i}.attn.wv"],
                "wo": tensors[f"blocks.{i}.attn.wo"],
                "ln2.g": tensors[f"blocks.{i}.ln2.g"],
                "ln2.b": tensors[f"blocks.{i}.ln2.b"],
                "w1": tensors[f"blocks.{i}.mlp.w1"],
                "b1": tensors[f"blocks.{i}.mlp.b1"],
                "w2": tensors[f"blocks.{i}.mlp.w2"],
                "b2": tensors[f"blocks.{i}.mlp.b2"],
            }
        )
    return params, cfg
