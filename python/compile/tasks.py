"""Synthetic task generators — Python mirror of rust/src/workload/tasks.rs.

Formats must stay byte-identical between the two implementations (the Rust
side evaluates what this side trains). Distributions match; exact instances
need not (different PRNGs).
"""

from __future__ import annotations

import numpy as np

VARS = "abcdefghijklmnopqrstuvwxyz"


def gen_program(rng: np.random.Generator, steps: int):
    """Returns (program_text, cot_text, answer_char)."""
    steps = max(2, min(24, steps))
    names = list(VARS)
    rng.shuffle(names)
    names = names[:steps]
    values: list[int] = []
    text, cot = [], []
    for i, name in enumerate(names):
        if i < 2:
            v = int(rng.integers(10))
            values.append(v)
            text.append(f"{name}={v};")
        else:
            a = int(rng.integers(i))
            b = int(rng.integers(i))
            if b == a:
                b = (b + 1) % i
            op = rng.choice(["+", "-", "*"])
            if op == "+":
                v = (values[a] + values[b]) % 10
            elif op == "-":
                v = (10 + values[a] - values[b]) % 10
            else:
                v = (values[a] * values[b]) % 10
            values.append(v)
            text.append(f"{name}={names[a]}{op}{names[b]};")
        cot.append(f"{name}={values[i]};")
    answer = str(values[-1])
    text.append(f"{names[-1]}?")
    cot.append(f">{answer}")
    return "".join(text), "".join(cot), answer


def chain_arith_instance(rng: np.random.Generator, steps: int, shots: int):
    """Returns (prompt, completion, answer)."""
    prompt = []
    for _ in range(shots):
        t, c, _ = gen_program(rng, steps)
        prompt.append(t + "\n" + c + "\n")
    t, c, ans = gen_program(rng, steps)
    prompt.append(t + "\n")
    return "".join(prompt), c + "\n", ans


def kv_recall_instance(rng: np.random.Generator, pairs: int):
    pairs = max(2, min(200, pairs))
    keys, vals, used = [], [], set()
    while len(keys) < pairs:
        k = f"{VARS[int(rng.integers(26))]}{int(rng.integers(10))}"
        if k not in used:
            used.add(k)
            keys.append(k)
            vals.append(int(rng.integers(10)))
    prompt = "".join(f"{k}={v};" for k, v in zip(keys, vals))
    qi = int(rng.integers(pairs))
    prompt += f"{keys[qi]}?\n"
    ans = str(vals[qi])
    return prompt, f">{ans}\n", ans


def training_example(rng: np.random.Generator):
    """Sample one (prompt, completion) pair from the training mixture."""
    if rng.random() < 0.55:
        steps = int(rng.integers(3, 7))
        shots = int(rng.integers(0, 3))
        p, c, _ = chain_arith_instance(rng, steps, shots)
    else:
        pairs = int(rng.integers(4, 24))
        p, c, _ = kv_recall_instance(rng, pairs)
    return p, c
