"""Layer-2 GEAR pipeline in JAX, composed from the layer-1 kernels.

This is the build-path mirror of ``rust/src/gear/compose.rs``: the same
D̂ + L + S decomposition, used to (a) validate kernels against ``ref.py``
at build time and (b) lower the fused decode-attention graph to HLO for
the Rust runtime.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import quant as kq
from .kernels import power_iter as kp
from .kernels import ref


def gear_compress_recon(x, kind: str, bits: int, group: int, s: float, r: int,
                        n_heads: int = 4, iters: int = 3, seed: int = 0):
    """GEAR reconstruction using the Pallas kernels.

    Mirrors ``ref.gear_ref`` but runs the quantization and power-iteration
    hot-spots through Pallas. Returns the reconstructed matrix.
    """
    axis = 0 if kind == "key" else 1
    sparse, rem = ref.filter_outliers_ref(x, s, axis)
    dq = kq.quant_dequant_pallas(rem, bits, axis, group)
    resid = rem - dq
    if r > 0:
        n, d = x.shape
        assert d % n_heads == 0
        dh = d // n_heads
        parts = []
        for h in range(n_heads):
            sub = resid[:, h * dh : (h + 1) * dh]
            a, b = kp.power_iter_pallas(sub, r, iters, seed + h)
            parts.append(a @ b.T)
        low = jnp.concatenate(parts, axis=1)
    else:
        low = 0.0
    return dq + low + sparse


def rel_error(x, xhat) -> float:
    return float(jnp.linalg.norm(x - xhat) / jnp.linalg.norm(x))
