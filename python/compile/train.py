"""Build-time trainer: fits the tiny-GPT on the synthetic task mixture and
writes ``artifacts/weights.bin`` (GSRV format, loaded by the Rust engine).

Runs once under ``make artifacts``; never on the request path. Training is
plain JAX with a hand-rolled Adam (no optax in the offline environment).

Env overrides: GEAR_TRAIN_STEPS, GEAR_TRAIN_BATCH, GEAR_TRAIN_SEED.
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks
from .model import (
    BOS,
    EOS,
    PAD,
    ModelConfig,
    encode,
    forward,
    init_params,
    save_checkpoint,
)

MAX_LEN = 384


def make_batch(rng: np.random.Generator, batch: int):
    """Pack (prompt, completion) pairs into padded id/weight arrays.

    Loss weights: 0.2 on prompt tokens (language modeling signal), 1.0 on
    completion tokens + EOS, 0 on padding.
    """
    toks = np.full((batch, MAX_LEN), PAD, np.int32)
    wts = np.zeros((batch, MAX_LEN), np.float32)
    for i in range(batch):
        while True:
            p, c = tasks.training_example(rng)
            ids = [BOS] + encode(p) + encode(c) + [EOS]
            if len(ids) <= MAX_LEN:
                break
        n = len(ids)
        plen = 1 + len(encode(p))
        toks[i, :n] = ids
        wts[i, 1:plen] = 0.05         # light LM signal on (mostly random) prompts
        wts[i, plen:n] = 1.0          # predict completion + EOS
    return jnp.asarray(toks), jnp.asarray(wts)


def loss_fn(params, cfg, toks, wts):
    logits = forward(params, cfg, toks[:, :-1])
    targets = toks[:, 1:]
    w = wts[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(out_path: str, steps: int, batch: int, seed: int, cfg: ModelConfig | None = None):
    cfg = cfg or ModelConfig()
    rng = np.random.default_rng(seed)
    params = init_params(cfg, seed)
    opt = adam_init(params)
    base_lr = 3e-3
    warmup = max(1, steps // 20)

    @jax.jit
    def step_fn(params, opt, toks, wts, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, toks, wts)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    for step in range(1, steps + 1):
        toks, wts = make_batch(rng, batch)
        frac = step / steps
        lr = base_lr * min(step / warmup, 0.5 * (1 + np.cos(np.pi * frac)) + 0.05)
        params, opt, loss = step_fn(params, opt, toks, wts, jnp.float32(lr))
        if step % max(1, steps // 20) == 0 or step == 1:
            print(
                f"[train] step {step}/{steps} loss {float(loss):.4f} "
                f"lr {lr:.2e} ({time.time() - t0:.0f}s)",
                flush=True,
            )

    acc = quick_eval(params, cfg, np.random.default_rng(seed + 1))
    print(f"[train] greedy eval: {acc}")
    save_checkpoint(out_path, params, cfg)
    print(f"[train] wrote {out_path}")
    return params, cfg, acc


def greedy_generate(params, cfg, prompt_ids, max_new=48):
    """Slow (re-prefill per token) greedy decoding, for eval only."""
    ids = list(prompt_ids)
    nl = encode("\n")[0]
    for _ in range(max_new):
        toks = jnp.asarray([ids], jnp.int32)
        logits = forward(params, cfg, toks)[0, -1]
        nxt = int(jnp.argmax(logits))
        if nxt in (EOS, nl):
            ids.append(nxt)
            break
        ids.append(nxt)
    return ids[len(prompt_ids):]


def quick_eval(params, cfg, rng, n=20):
    """Answer accuracy on held-out instances of both tasks."""
    from .model import decode_ids

    results = {}
    for name, gen in [
        ("chain-arith", lambda: tasks.chain_arith_instance(rng, 5, 2)),
        ("kv-recall", lambda: tasks.kv_recall_instance(rng, 16)),
    ]:
        correct = 0
        for _ in range(n):
            p, _, ans = gen()
            out = greedy_generate(params, cfg, [BOS] + encode(p))
            text = decode_ids(out)
            got = text[text.rfind(">") + 1 : text.rfind(">") + 2] if ">" in text else ""
            correct += got == ans
        results[name] = correct / n
    return results


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts/weights.bin"
    steps = int(os.environ.get("GEAR_TRAIN_STEPS", "1500"))
    batch = int(os.environ.get("GEAR_TRAIN_BATCH", "8"))
    seed = int(os.environ.get("GEAR_TRAIN_SEED", "0"))
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    train(out, steps, batch, seed)


if __name__ == "__main__":
    main()
