"""AOT lowering: JAX graphs -> HLO text artifacts for the Rust runtime.

Emits HLO *text* (never ``.serialize()``): jax >= 0.5 writes protos with
64-bit instruction ids that the runtime's xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):
  weights.bin              trained checkpoint (trains first if missing)
  prefill_{n}.hlo.txt      tokens [1,n] -> (last_logits, K [L,n,d], V [L,n,d])
  decode_{n}.hlo.txt       (token, pos, K, V, cur_len) -> (logits, k_new, v_new)
  gear_attn_{n}.hlo.txt    fused GEAR decode attention (Pallas, interpret)
  golden/*.bin             cross-language test vectors (GSRV tensor maps)
  manifest.txt             key=value description of everything above

Weights are passed as runtime *arguments* in the manifest's `param_order`
(never baked as constants: the HLO text printer elides large literals).
"""

from __future__ import annotations

import argparse
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tasks
from .kernels import fused_attn, ref
from .model import (
    BOS,
    ModelConfig,
    decode_graph,
    encode,
    forward,
    load_checkpoint,
    prefill_graph,
)

PREFILL_BUCKETS = [64, 128, 256]
DECODE_BUCKETS = [128, 256, 512]
GEAR_ATTN_BUCKET = 256
GOLDEN_PROMPT = "a=3;b=7;c=a+b;d=c*b;d?\n"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_tensor_map(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    """GSRV tensor-map format (rust/src/model/weights.rs::read_tensor_map)."""
    with open(path, "wb") as f:
        f.write(b"GSRV")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr, np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.astype("<f4").tobytes())


def param_order(params, cfg: ModelConfig) -> list[str]:
    """GSRV tensor names in jax pytree-flatten order.

    Weights are passed as runtime arguments (NOT baked as constants: the
    HLO *text* printer elides large literals as ``constant({...})``, which
    silently corrupts them through the text interchange). The Rust runtime
    rebuilds the argument list from weights.bin in exactly this order.
    """
    import jax.tree_util as jtu

    def path_to_name(path) -> str:
        keys = []
        for p in path:
            if isinstance(p, jtu.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jtu.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        if keys[0] == "blocks":
            i, leaf = keys[1], keys[2]
            if leaf in ("wq", "wk", "wv", "wo"):
                return f"blocks.{i}.attn.{leaf}"
            if leaf in ("w1", "w2", "b1", "b2"):
                return f"blocks.{i}.mlp.{leaf}"
            return f"blocks.{i}.{leaf}"
        return keys[0]

    leaves = jtu.tree_flatten_with_path(params)[0]
    return [path_to_name(path) for path, _ in leaves]


def lower_model_graphs(params, cfg: ModelConfig, outdir: str, manifest: list[str]) -> None:
    pspec = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    manifest.append("param_order=" + ",".join(param_order(params, cfg)))

    # Prefill buckets.
    for n in PREFILL_BUCKETS:
        fn = jax.jit(lambda p, toks: prefill_graph(p, cfg, toks))
        spec = jax.ShapeDtypeStruct((1, n), jnp.int32)
        path = os.path.join(outdir, f"prefill_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(fn.lower(pspec, spec)))
        manifest.append(f"prefill_{n}=prefill_{n}.hlo.txt")
        print(f"[aot] wrote {path}")

    # Decode buckets.
    for n in DECODE_BUCKETS:
        fn = jax.jit(
            lambda p, token, pos, k, v, cur: decode_graph(p, cfg, token, pos, k, v, cur)
        )
        s_i = jax.ShapeDtypeStruct((), jnp.int32)
        s_kv = jax.ShapeDtypeStruct((cfg.n_layers, n, cfg.d_model), jnp.float32)
        path = os.path.join(outdir, f"decode_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(fn.lower(pspec, s_i, s_i, s_kv, s_kv, s_i)))
        manifest.append(f"decode_{n}=decode_{n}.hlo.txt")
        print(f"[aot] wrote {path}")


def lower_gear_attn(cfg: ModelConfig, outdir: str, manifest: list[str]) -> None:
    n, d, h, r = GEAR_ATTN_BUCKET, cfg.d_model, cfg.n_heads, 4
    dh = d // h
    fn = jax.jit(
        lambda q, codes, scales, zeros, a, b, v, cur: fused_attn.gear_attn_pallas(
            q, codes, scales, zeros, a, b, v, cur, n_heads=h
        )
    )
    specs = (
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((n, d), jnp.int32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((h, n, r), jnp.float32),
        jax.ShapeDtypeStruct((h, dh, r), jnp.float32),
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    path = os.path.join(outdir, f"gear_attn_{n}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(fn.lower(*specs)))
    manifest.append(f"gear_attn_{n}=gear_attn_{n}.hlo.txt")
    print(f"[aot] wrote {path}")


def write_golden(params, cfg: ModelConfig, outdir: str, manifest: list[str]) -> None:
    gdir = os.path.join(outdir, "golden")
    os.makedirs(gdir, exist_ok=True)

    # (1) Model parity: prompt ids -> full-forward last logits.
    ids = np.array([BOS] + encode(GOLDEN_PROMPT), np.int32)
    logits = forward(params, cfg, jnp.asarray(ids[None, :]))[0, -1]
    write_tensor_map(
        os.path.join(gdir, "parity.bin"),
        [("tokens", ids.astype(np.float32)), ("last_logits", np.asarray(logits))],
    )
    manifest.append("golden_parity=golden/parity.bin")

    # (2) Quantization vectors: shared input, dequant under several schemes.
    rng = np.random.default_rng(1234)
    x = rng.normal(size=(48, 32)).astype(np.float32)
    x[:, 5] *= 9.0  # a heavy channel
    tensors: list[tuple[str, np.ndarray]] = [("x", x)]
    for bits, axis, group, name in [
        (4, 1, 16, "deq_b4_row_g16"),
        (2, 1, 32, "deq_b2_row_g32"),
        (2, 0, 48, "deq_b2_col_full"),
        (8, 1, 32, "deq_b8_row_g32"),
    ]:
        deq = ref.quant_dequant_ref(jnp.asarray(x), bits, axis, group)
        tensors.append((name, np.asarray(deq)))
    write_tensor_map(os.path.join(gdir, "quant.bin"), tensors)
    manifest.append("golden_quant=golden/quant.bin")

    # (3) Outlier filter vectors.
    sp, rem = ref.filter_outliers_ref(jnp.asarray(x), 0.125, 1)
    write_tensor_map(
        os.path.join(gdir, "outlier.bin"),
        [("x", x), ("sparse", np.asarray(sp)), ("remainder", np.asarray(rem))],
    )
    manifest.append("golden_outlier=golden/outlier.bin")

    # (4) Fused attention oracle (used to validate both the Pallas kernel's
    # HLO artifact and the Rust fused path).
    n, d, h, r = 32, cfg.d_model, cfg.n_heads, 4
    dh = d // h
    q = rng.normal(size=(d,)).astype(np.float32)
    codes = rng.integers(0, 16, size=(n, d)).astype(np.int32)
    scales = (np.abs(rng.normal(size=(d,))) * 0.1 + 0.01).astype(np.float32)
    zeros = rng.normal(size=(d,)).astype(np.float32) * 0.1
    a = rng.normal(size=(h, n, r)).astype(np.float32) * 0.05
    b = rng.normal(size=(h, dh, r)).astype(np.float32) * 0.05
    v = rng.normal(size=(n, d)).astype(np.float32)
    ctx = ref.gear_attn_ref(
        jnp.asarray(q), jnp.asarray(codes), jnp.asarray(scales), jnp.asarray(zeros),
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(v), h,
    )
    write_tensor_map(
        os.path.join(gdir, "gear_attn.bin"),
        [
            ("q", q),
            ("codes", codes.astype(np.float32)),
            ("scales", scales),
            ("zeros", zeros),
            ("a", a),
            ("b", b),
            ("v", v),
            ("ctx", np.asarray(ctx)),
        ],
    )
    manifest.append("golden_gear_attn=golden/gear_attn.bin")
    print(f"[aot] wrote golden vectors to {gdir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--skip-model-graphs", action="store_true",
                    help="only weights + golden (fast CI path)")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    weights_path = os.path.join(outdir, "weights.bin")
    if not os.path.exists(weights_path):
        print("[aot] no checkpoint found; training (set GEAR_TRAIN_STEPS to tune)")
        from .train import train

        steps = int(os.environ.get("GEAR_TRAIN_STEPS", "1500"))
        batch = int(os.environ.get("GEAR_TRAIN_BATCH", "8"))
        train(weights_path, steps, batch, seed=0)
    params, cfg = load_checkpoint(weights_path)
    print(f"[aot] model {cfg}")

    manifest: list[str] = [
        f"vocab={cfg.vocab}",
        f"d_model={cfg.d_model}",
        f"n_layers={cfg.n_layers}",
        f"n_heads={cfg.n_heads}",
        f"max_seq={cfg.max_seq}",
        "weights=weights.bin",
        f"golden_prompt={GOLDEN_PROMPT!r}",
    ]
    write_golden(params, cfg, outdir, manifest)
    if not args.skip_model_graphs:
        lower_model_graphs(params, cfg, outdir, manifest)
        lower_gear_attn(cfg, outdir, manifest)

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] manifest with {len(manifest)} entries")


if __name__ == "__main__":
    main()
