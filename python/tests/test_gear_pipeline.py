"""GEAR pipeline behaviour in JAX: the paper's error-ordering claims must
hold at the kernel level before anything touches the serving stack."""

import jax.numpy as jnp
import numpy as np

from compile import gear
from compile.kernels import ref


def kv_like(seed, n, d, kind):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    tail = 1.0 if kind == "key" else 0.3
    x *= np.exp(rng.normal(0, tail, size=d)).astype(np.float32)[None, :]
    mask = rng.random(size=(n, d)) < 0.01
    x = np.where(mask, x * 8, x)
    return jnp.asarray(x)


def err(x, recon):
    return float(jnp.linalg.norm(x - recon) / jnp.linalg.norm(x))


def test_gear_reduces_error_over_quant_only():
    for kind in ["key", "value"]:
        x = kv_like(0, 128, 64, kind)
        e_q = err(x, gear.gear_compress_recon(x, kind, 2, 32, 0.0, 0))
        e_gl = err(x, gear.gear_compress_recon(x, kind, 2, 32, 0.0, 4))
        e_g = err(x, gear.gear_compress_recon(x, kind, 2, 32, 0.02, 4))
        assert e_gl < e_q, f"{kind}: GEAR-L {e_gl} !< quant {e_q}"
        assert e_g < e_q, f"{kind}: GEAR {e_g} !< quant {e_q}"


def test_pallas_pipeline_matches_ref_pipeline():
    x = kv_like(1, 96, 32, "key")
    got = gear.gear_compress_recon(x, "key", 2, 32, 0.02, 4)
    want = ref.gear_ref(x, "key", 2, 32, 0.02, 4)
    # Same quant + outlier semantics; low-rank uses the same PRNG seed.
    assert abs(err(x, got) - err(x, want)) < 0.02


def test_higher_bits_lower_error():
    x = kv_like(2, 96, 32, "value")
    errs = [err(x, gear.gear_compress_recon(x, "value", b, 32, 0.02, 4)) for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_residual_spectrum_decays():
    # Fig 2b: quantization residual has fast-decaying spectrum.
    x = kv_like(3, 128, 64, "key")
    dq = ref.quant_dequant_ref(x, 2, 0, 128)
    resid = np.asarray(x - dq)
    sv = np.linalg.svd(resid[:, :16], compute_uv=False)
    energy = (sv**2) / (sv**2).sum()
    assert energy[:4].sum() > 0.25, f"top-4 energy {energy[:4].sum()}"
