"""Model graph shape/consistency tests + checkpoint round-trip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    BOS,
    EOS,
    VOCAB_SIZE,
    ModelConfig,
    decode_graph,
    decode_ids,
    encode,
    encode_with_bos,
    forward,
    init_params,
    load_checkpoint,
    prefill_graph,
    save_checkpoint,
)

CFG = ModelConfig(vocab=VOCAB_SIZE, d_model=32, n_layers=2, n_heads=4, max_seq=64)


def test_tokenizer_roundtrip():
    s = "a=3;b=7;c=a+b;c?\n>0"
    assert decode_ids(encode(s)) == s
    assert encode("0") == [3] and encode("a") == [13] and encode("\n") == [48]
    assert encode_with_bos("a")[0] == BOS


def test_forward_shapes_and_finite():
    params = init_params(CFG, 0)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, CFG.vocab, (2, 10)), jnp.int32)
    logits = forward(params, CFG, toks)
    assert logits.shape == (2, 10, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_prefill_graph_matches_forward():
    params = init_params(CFG, 0)
    ids = jnp.asarray([[BOS] + encode("a=1;a?\n")], jnp.int32)
    full = forward(params, CFG, ids)[0, -1]
    last, k, v = prefill_graph(params, CFG, ids)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full), rtol=1e-5, atol=1e-5)
    assert k.shape == (CFG.n_layers, ids.shape[1], CFG.d_model)
    assert v.shape == k.shape


def test_decode_graph_matches_forward():
    """Incremental decode with the dense-cache graph == full forward."""
    params = init_params(CFG, 0)
    ids = [BOS] + encode("a=1;b=2;a?\n")
    n_bucket = 32
    _, k, v = prefill_graph(params, CFG, jnp.asarray([ids], jnp.int32))
    kc = jnp.zeros((CFG.n_layers, n_bucket, CFG.d_model)).at[:, : len(ids)].set(k)
    vc = jnp.zeros((CFG.n_layers, n_bucket, CFG.d_model)).at[:, : len(ids)].set(v)
    tok = encode("0")[0]
    logits, k_new, v_new = decode_graph(
        params, CFG, jnp.int32(tok), jnp.int32(len(ids)), kc, vc, jnp.int32(len(ids))
    )
    ref = forward(params, CFG, jnp.asarray([ids + [tok]], jnp.int32))[0, -1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert k_new.shape == (CFG.n_layers, CFG.d_model)
    assert v_new.shape == (CFG.n_layers, CFG.d_model)


def test_checkpoint_roundtrip():
    params = init_params(CFG, 3)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "w.bin")
        save_checkpoint(path, params, CFG)
        params2, cfg2 = load_checkpoint(path)
        assert cfg2 == CFG
        toks = jnp.asarray([[BOS, 5, 6]], jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(forward(params, CFG, toks)), np.asarray(forward(params2, cfg2, toks))
        )


def test_causality():
    """Changing a future token must not change past logits."""
    params = init_params(CFG, 1)
    a = jnp.asarray([[BOS, 5, 6, 7, 8]], jnp.int32)
    b = a.at[0, 4].set(9)
    la = forward(params, CFG, a)
    lb = forward(params, CFG, b)
    np.testing.assert_allclose(np.asarray(la[0, :4]), np.asarray(lb[0, :4]), atol=1e-6)
