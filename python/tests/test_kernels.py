"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_attn, power_iter, quant, ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def randmat(seed, n, d, heavy_channels=False):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    if heavy_channels:
        scale = np.exp(rng.normal(0, 1.0, size=d)).astype(np.float32)
        x *= scale[None, :]
    return jnp.asarray(x)


# --- quant kernel ------------------------------------------------------------


@given(
    n=st.integers(1, 90),
    d=st.integers(1, 70),
    bits=st.sampled_from([2, 4, 8]),
    group=st.integers(1, 80),
    axis=st.sampled_from([0, 1]),
    seed=st.integers(0, 10_000),
)
def test_quant_pallas_matches_ref(n, d, bits, group, axis, seed):
    x = randmat(seed, n, d)
    got = quant.quant_dequant_pallas(x, bits, axis, group)
    want = ref.quant_dequant_ref(x, bits, axis, group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_error_bounded_by_half_step(bits):
    x = randmat(7, 64, 32)
    deq = quant.quant_dequant_pallas(x, bits, 1, 16)
    # Per group of 16, error <= (max-min)/(2^b-1)/2.
    xg = np.asarray(x).reshape(64, 2, 16)
    step = (xg.max(-1) - xg.min(-1)) / (2**bits - 1)
    err = np.abs(np.asarray(deq).reshape(64, 2, 16) - xg)
    assert (err <= step[..., None] / 2 + 1e-5).all()


def test_kcvt_key_is_per_channel():
    # A constant column must be reproduced exactly regardless of other
    # columns' ranges (per-channel grouping isolates it).
    x = np.asarray(randmat(3, 40, 8)).copy()
    x[:, 2] = 5.0
    deq = quant.kcvt_pallas(jnp.asarray(x), 2, "key")
    np.testing.assert_allclose(np.asarray(deq)[:, 2], 5.0, atol=1e-6)


def test_eight_bit_nearly_lossless():
    x = randmat(11, 128, 64, heavy_channels=True)
    deq = quant.quant_dequant_pallas(x, 8, 0, 128)
    rel = float(jnp.linalg.norm(x - deq) / jnp.linalg.norm(x))
    assert rel < 0.01


# --- outlier filter ----------------------------------------------------------


@given(
    n=st.integers(4, 60),
    d=st.integers(4, 60),
    s=st.sampled_from([0.0, 0.02, 0.1, 0.25]),
    axis=st.sampled_from([0, 1]),
    seed=st.integers(0, 10_000),
)
def test_outlier_split_is_exact(n, d, s, axis, seed):
    x = randmat(seed, n, d)
    sp, rem = ref.filter_outliers_ref(x, s, axis)
    np.testing.assert_allclose(np.asarray(sp + rem), np.asarray(x), atol=1e-6)
    vec_len = n if axis == 0 else d
    k = int(round(vec_len * s / 2.0))
    n_vecs = d if axis == 0 else n
    assert int((np.asarray(sp) != 0).sum()) <= 2 * k * n_vecs


def test_outliers_are_extremes():
    x = np.zeros((4, 32), np.float32)
    x += np.random.default_rng(0).normal(0, 0.1, x.shape).astype(np.float32)
    x[:, 3] = 50.0
    x[:, 17] = -50.0
    sp, rem = ref.filter_outliers_ref(jnp.asarray(x), 0.0625, 1)  # k=1/side
    assert (np.asarray(sp)[:, 3] == 50.0).all()
    assert (np.asarray(sp)[:, 17] == -50.0).all()
    assert np.abs(np.asarray(rem)).max() < 1.0


# --- power iteration ---------------------------------------------------------


@given(
    n=st.integers(8, 48),
    d=st.integers(8, 48),
    r=st.integers(1, 6),
    seed=st.integers(0, 1_000),
)
def test_power_iter_pallas_matches_ref(n, d, r, seed):
    x = randmat(seed, n, d)
    a1, b1 = power_iter.power_iter_pallas(x, r, 4, seed=0)
    a2, b2 = ref.power_iter_ref(x, r, 4, seed=0)
    # Factors must agree (same PRNG + same sweeps -> identical).
    np.testing.assert_allclose(np.asarray(a1 @ b1.T), np.asarray(a2 @ b2.T), atol=1e-3)


def test_power_iter_recovers_planted_rank():
    rng = np.random.default_rng(5)
    u = rng.normal(size=(64, 3)).astype(np.float32)
    v = rng.normal(size=(3, 32)).astype(np.float32)
    x = jnp.asarray(u @ v)
    a, b = power_iter.power_iter_pallas(x, 3, 5)
    resid = float(jnp.linalg.norm(x - a @ b.T) / jnp.linalg.norm(x))
    assert resid < 1e-2


def test_power_iter_residual_close_to_svd():
    x = randmat(9, 40, 24, heavy_channels=True)
    r = 4
    a, b = power_iter.power_iter_pallas(x, r, 6)
    resid = float(jnp.linalg.norm(x - a @ b.T))
    sv = np.linalg.svd(np.asarray(x), compute_uv=False)
    exact = float(np.sqrt((sv[r:] ** 2).sum()))
    assert resid <= exact * 1.2 + 1e-6


# --- fused attention ---------------------------------------------------------


@given(
    n=st.integers(2, 40),
    heads=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 1_000),
)
def test_gear_attn_pallas_matches_ref(n, heads, seed):
    d, r = 32, 3
    dh = d // heads
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 4, size=(n, d)), jnp.int32)
    scales = jnp.asarray(np.abs(rng.normal(size=(d,))) * 0.2 + 0.01, jnp.float32)
    zeros = jnp.asarray(rng.normal(size=(d,)) * 0.1, jnp.float32)
    a = jnp.asarray(rng.normal(size=(heads, n, r)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(heads, dh, r)) * 0.1, jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    got = fused_attn.gear_attn_pallas(q, codes, scales, zeros, a, b, v, n, heads)
    want = ref.gear_attn_ref(q, codes, scales, zeros, a, b, v, heads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_gear_attn_masks_invalid_rows():
    # Rows beyond cur_len must not affect the output.
    d, n, heads = 16, 8, 2
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 4, size=(n, d)), jnp.int32)
    scales = jnp.ones((d,), jnp.float32) * 0.1
    zeros = jnp.zeros((d,), jnp.float32)
    a = jnp.zeros((heads, n, 2), jnp.float32)
    b = jnp.zeros((heads, d // heads, 2), jnp.float32)
    v1 = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v2 = v1.at[5:].set(999.0)
    o1 = fused_attn.gear_attn_pallas(q, codes, scales, zeros, a, b, v1, 5, heads)
    o2 = fused_attn.gear_attn_pallas(q, codes, scales, zeros, a, b, v2, 5, heads)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
