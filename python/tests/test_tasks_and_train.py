"""Task generators + one smoke training step."""

import numpy as np

from compile import tasks
from compile.model import ModelConfig, VOCAB_SIZE, encode


def test_chain_arith_answer_correct():
    rng = np.random.default_rng(0)
    for _ in range(30):
        text, cot, ans = tasks.gen_program(rng, 5)
        # Independent evaluator.
        env = {}
        stmts = text[:-2].split(";")  # strip "x?"
        query = text[-2]
        for stmt in stmts:
            if not stmt:
                continue
            lhs, rhs = stmt.split("=")
            if len(rhs) == 1 and rhs.isdigit():
                env[lhs] = int(rhs)
            else:
                a, op, b = rhs[0], rhs[1], rhs[2]
                if op == "+":
                    env[lhs] = (env[a] + env[b]) % 10
                elif op == "-":
                    env[lhs] = (10 + env[a] - env[b]) % 10
                else:
                    env[lhs] = (env[a] * env[b]) % 10
        assert str(env[query]) == ans, text
        assert cot.endswith(f">{ans}")


def test_kv_recall_binding():
    rng = np.random.default_rng(1)
    for _ in range(30):
        prompt, completion, ans = tasks.kv_recall_instance(rng, 12)
        q = prompt.rstrip("\n").split(";")[-1].rstrip("?")
        binding = [s for s in prompt.split(";") if s.startswith(q + "=")][0]
        assert binding.endswith(ans)
        assert completion == f">{ans}\n"


def test_everything_tokenizes():
    rng = np.random.default_rng(2)
    for _ in range(20):
        p, c = tasks.training_example(rng)
        ids = encode(p) + encode(c)
        assert all(0 <= i < VOCAB_SIZE for i in ids)


def test_one_training_step_reduces_loss_eventually():
    """Tiny smoke: a few steps on a tiny model must not diverge."""
    import jax.numpy as jnp
    from compile.train import adam_init, adam_update, loss_fn, make_batch
    import jax

    cfg = ModelConfig(vocab=VOCAB_SIZE, d_model=32, n_layers=2, n_heads=4, max_seq=64)
    from compile.model import init_params

    params = init_params(cfg, 0)
    opt = adam_init(params)
    rng = np.random.default_rng(0)

    import compile.train as train_mod

    old = train_mod.MAX_LEN
    train_mod.MAX_LEN = 64
    try:
        losses = []
        for _ in range(5):
            # Use short kv-recall examples that fit 64 tokens.
            toks = np.full((4, 64), 0, np.int32)
            wts = np.zeros((4, 64), np.float32)
            for i in range(4):
                p, c, _ = tasks.kv_recall_instance(rng, 4)
                ids = [1] + encode(p) + encode(c) + [2]
                toks[i, : len(ids)] = ids
                wts[i, 1 : len(ids)] = 1.0
            toks, wts = jnp.asarray(toks), jnp.asarray(wts)
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, toks, wts)
            params, opt = adam_update(params, grads, opt, 1e-3)
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        assert all(np.isfinite(losses))
    finally:
        train_mod.MAX_LEN = old
