//! Cross-language golden tests: the JAX build path (python/compile) writes
//! vectors into artifacts/golden/, the Rust request path must reproduce
//! them. Skips (with a note) when `make artifacts` hasn't run.

use std::path::PathBuf;

use gear_serve::gear::quant::{QuantScheme, QuantizedMatrix};
use gear_serve::gear::outlier::filter_outliers;
use gear_serve::gear::Axis;
use gear_serve::model::weights::read_tensor_map;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::tensor::Tensor;

fn golden(name: &str) -> Option<std::collections::HashMap<String, Tensor>> {
    if !Artifacts::available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let path: PathBuf = Artifacts::default_dir().join("golden").join(name);
    let bytes = std::fs::read(&path).expect("golden file");
    Some(read_tensor_map(&bytes).expect("golden parse"))
}

fn assert_close(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= atol, "{what}: max abs diff {worst} > {atol}");
}

#[test]
fn quantization_matches_jax() {
    let Some(g) = golden("quant.bin") else { return };
    let x = g["x"].clone();
    for (name, bits, scheme) in [
        ("deq_b4_row_g16", 4u8, QuantScheme::per_token_group(16)),
        ("deq_b2_row_g32", 2, QuantScheme::per_token_group(32)),
        (
            "deq_b2_col_full",
            2,
            QuantScheme { axis: Axis::Col, group: gear_serve::gear::GroupSize::Full },
        ),
        ("deq_b8_row_g32", 8, QuantScheme::per_token_group(32)),
    ] {
        let q = QuantizedMatrix::quantize(&x, bits, scheme);
        let deq = q.dequantize();
        // FP16 rounding of scales/zeros on the Rust side vs f32 in the jnp
        // oracle: half a step, plus the scale's FP16 relative error
        // amplified by up to `levels` codes, plus zero-point rounding.
        let levels = ((1u32 << bits) - 1) as f32;
        let tol = q.max_step() * (0.51 + levels * 6e-4) + 5e-2;
        assert_close(deq.data(), g[name].data(), tol, name);
    }
}

#[test]
fn outlier_filter_matches_jax() {
    let Some(g) = golden("outlier.bin") else { return };
    let x = g["x"].clone();
    let (sp, rem) = filter_outliers(&x, 0.125, Axis::Row);
    assert_close(rem.data(), g["remainder"].data(), 2e-2, "remainder");
    assert_close(sp.to_dense().data(), g["sparse"].data(), 2e-2, "sparse");
}

#[test]
fn fused_attention_matches_jax_oracle() {
    let Some(g) = golden("gear_attn.bin") else { return };
    let codes = &g["codes"];
    let (n, d) = (codes.rows(), codes.cols());
    let scales = g["scales"].data();
    let zeros = g["zeros"].data();
    // Rebuild dense K = zeros + codes * scales + concat_h(A_h B_h^T).
    let a = &g["a"]; // [H, n, r]
    let b = &g["b"]; // [H, dh, r]
    let h = a.shape()[0];
    let r = a.shape()[2];
    let dh = d / h;
    let mut k = vec![0.0f32; n * d];
    for t in 0..n {
        for c in 0..d {
            k[t * d + c] = zeros[c] + codes.data()[t * d + c] * scales[c];
            let hh = c / dh;
            let cc = c % dh;
            for ri in 0..r {
                k[t * d + c] +=
                    a.data()[hh * n * r + t * r + ri] * b.data()[hh * dh * r + cc * r + ri];
            }
        }
    }
    // Rust attention over dense K/V must equal the JAX oracle ctx.
    use gear_serve::kvcache::{dense::DenseLayerKv, LayerKv};
    let mut cache = DenseLayerKv::new(d);
    cache.ingest_prefill(
        Tensor::new(&[n, d], k),
        g["v"].clone(),
        None,
    );
    let mut out = vec![0.0f32; d];
    cache.attend(g["q"].data(), h, &mut out);
    // fp16 rounding inside DenseLayerKv + f32 assoc. differences.
    assert_close(&out, g["ctx"].data(), 5e-2, "ctx");
}

#[test]
fn model_logits_match_jax_forward() {
    let Some(g) = golden("parity.bin") else { return };
    let weights = ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap();
    let model = Model::new(weights);
    let tokens: Vec<u32> = g["tokens"].data().iter().map(|&t| t as u32).collect();
    let c = model.config();
    let mut cache = gear_serve::kvcache::RequestCache::new(
        &gear_serve::kvcache::CacheSpec::Fp16,
        c.n_layers,
        c.d_model,
        c.n_heads,
    );
    let out = model.prefill(&tokens, &mut cache);
    let want = g["last_logits"].data();
    // Different accumulation orders across languages: compare both absolute
    // and argmax (the serving-relevant signal).
    assert_close(&out.last_logits, want, 0.05, "last_logits");
    let am_rust = gear_serve::model::sampler::argmax(&out.last_logits);
    let am_jax = gear_serve::model::sampler::argmax(want);
    assert_eq!(am_rust, am_jax, "argmax mismatch");
}
