//! Edge-case and error-bound tests for the GEAR compression components
//! (`gear::{quant, lowrank, outlier, compose}`): degenerate inputs the
//! serving path can produce (zero and constant matrices, ranks at or
//! past the matrix dimensions, outlier fractions that round to zero
//! entries), plus a randomized property pinning Eq. (4)'s error
//! structure — the reconstruction error of the composite never exceeds
//! the bound its own components predict.

use gear_serve::gear::compose::{compress, Backbone, CompressedMatrix, GearConfig};
use gear_serve::gear::error::rel_error;
use gear_serve::gear::lowrank::power_iter_lowrank;
use gear_serve::gear::outlier::{filter_outliers, k_per_side};
use gear_serve::gear::quant::{Axis, QuantScheme, QuantizedMatrix};
use gear_serve::gear::{KvKind, Method};
use gear_serve::prop_assert;
use gear_serve::tensor::Tensor;
use gear_serve::util::prop::{forall, gen_kv_like, Config};
use gear_serve::util::rng::Rng;

fn kv_matrix(r: &mut Rng, rows: usize, cols: usize) -> Tensor {
    Tensor::new(&[rows, cols], gen_kv_like(r, rows * cols))
}

/// A zero matrix compresses to an exact zero reconstruction under every
/// method: degenerate groups quantize at scale 0, the outlier filter
/// extracts only zeros, and the low-rank fit of a zero residual is a
/// zero product (its factors may be degenerate, the product may not).
#[test]
fn zero_matrix_reconstructs_exactly() {
    let x = Tensor::zeros(&[16, 32]);
    for m in [
        Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(8) },
        Method::OutlierAware { bits: 2, backbone: Backbone::Kcvt, s: 0.1 },
        Method::gear_l_default(2),
        Method::gear_default(2),
        Method::LowRankOnly { r: 4 },
    ] {
        for kind in [KvKind::Key, KvKind::Value] {
            let c = compress(&x, kind, &GearConfig::new(m, 4));
            assert!(
                c.reconstruct().data().iter().all(|&v| v == 0.0),
                "{m:?} {kind:?}: zero matrix reconstructed non-zero"
            );
            assert_eq!(rel_error(x.data(), c.reconstruct().data()), 0.0);
        }
    }
    let q = QuantizedMatrix::quantize(&x, 2, QuantScheme::per_token_group(8));
    assert_eq!(q.max_step(), 0.0, "zero matrix must quantize at scale 0");
}

/// A constant matrix is a single-value group everywhere: scale 0, the
/// zero-point carries the value, and the GEAR-L residual is exactly
/// zero — so the reconstruction is exact, not approximate. 3.25 is
/// FP16-representable, so zero-point rounding cannot perturb it.
#[test]
fn constant_matrix_reconstructs_exactly() {
    let x = Tensor::filled(&[12, 16], 3.25);
    for m in [
        Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(4) },
        Method::QuantOnly { bits: 4, backbone: Backbone::Kcvt },
        Method::GearL { bits: 2, backbone: Backbone::Kivi(4), r: 4 },
    ] {
        let c = compress(&x, KvKind::Key, &GearConfig::new(m, 4));
        for (i, v) in c.reconstruct().data().iter().enumerate() {
            assert_eq!(*v, 3.25, "{m:?}: entry {i} drifted");
        }
    }
}

/// Requested ranks at or beyond min(n, d) clamp to min(n, d): the
/// factorization is then full-rank and recovers the matrix to the FP16
/// precision of its stored factors. Rank 0 clamps up to 1.
#[test]
fn rank_clamps_to_matrix_dimensions() {
    let mut rng = Rng::new(77);
    let x = kv_matrix(&mut rng, 8, 4);
    for req in [4usize, 8, 100] {
        let lr = power_iter_lowrank(x.data(), 8, 4, req, 4, &mut rng);
        assert_eq!(lr.r, 4, "requested rank {req} must clamp to min(8, 4)");
        let rel = rel_error(x.data(), lr.to_dense().data());
        assert!(rel < 2e-2, "full-rank fit (req {req}) rel err {rel}");
    }
    let lr = power_iter_lowrank(x.data(), 8, 4, 0, 4, &mut rng);
    assert_eq!(lr.r, 1, "rank 0 must clamp up to 1");
}

/// An outlier fraction whose entry count rounds to zero is a no-op:
/// empty sparse matrix, remainder bitwise equal to the input. 64
/// entries at s = 1% give 0.32 entries per side, which rounds to 0.
#[test]
fn outlier_fraction_rounding_to_zero_is_noop() {
    assert_eq!(k_per_side(64, 0.01), 0);
    assert_eq!(k_per_side(64, 0.02), 1); // sanity: the paper's s = 2% is not a no-op
    let mut rng = Rng::new(78);
    let x = kv_matrix(&mut rng, 8, 64);
    for axis in [Axis::Row, Axis::Col] {
        // Along Col the vectors are 8 long: 8 * 0.01 / 2 rounds to 0 too.
        let (s, rem) = filter_outliers(&x, 0.01, axis);
        assert_eq!(s.nnz(), 0, "{axis:?}: rounded-to-zero fraction extracted entries");
        assert_eq!(rem.data(), x.data(), "{axis:?}: remainder must be untouched");
    }
    // Through the composite: full GEAR with a no-op fraction must match
    // GEAR-L exactly (same backbone, same residual, same seed).
    let gear = compress(
        &x,
        KvKind::Value,
        &GearConfig::new(Method::Gear { bits: 2, backbone: Backbone::Kivi(8), s: 0.01, r: 4 }, 4),
    );
    let gearl = compress(
        &x,
        KvKind::Value,
        &GearConfig::new(Method::GearL { bits: 2, backbone: Backbone::Kivi(8), r: 4 }, 4),
    );
    assert_eq!(gear.sparse.as_ref().map(|s| s.nnz()), Some(0));
    assert_eq!(gear.reconstruct().data(), gearl.reconstruct().data());
}

/// Eq. (4) error structure, as a randomized property. Two bounds the
/// decomposition `X ≈ D̂ + L + S` itself predicts:
///
/// * backbone: every entry of the quantized remainder is within half a
///   quantization step of `D̂` (+ FP16 rounding of scale/zero), so the
///   quant + sparse partial reconstruction obeys the per-entry bound
///   `|X − D̂ − S| ≤ max_step / 2 + ε`;
/// * low-rank: `L` is a least-squares fit of the residual `R = X − D̂ −
///   S`, so adding it cannot exceed the error of leaving it out —
///   `‖X − X̂‖_F` is bounded by the partial reconstruction's error.
#[test]
fn prop_eq4_error_within_predicted_bound() {
    forall(
        Config { cases: 64, seed: 0x6EA4_0004 },
        |r| {
            let rows = 8 + r.next_below(56) as usize;
            let cols = *r.choose(&[16usize, 32, 64]);
            let bits = *r.choose(&[2u8, 4]);
            let s = *r.choose(&[0.0f64, 0.02, 0.05]);
            let rank = 1 + r.next_below(6) as usize;
            (kv_matrix(r, rows, cols), bits, s, rank)
        },
        |(x, bits, s, rank)| {
            let method = Method::Gear { bits: *bits, backbone: Backbone::Kivi(16), s: *s, r: *rank };
            let c = compress(x, KvKind::Value, &GearConfig::new(method, 4));

            // Partial reconstruction D̂ + S (the term the low-rank fit
            // refines), reusing the component sum contract.
            let partial = CompressedMatrix { lowrank: None, ..c.clone() };
            let q = c.quant.as_ref().expect("GEAR always stores a backbone");
            let step_bound = f64::from(q.max_step()) * 0.5 + 1e-2;
            for (i, (a, b)) in x.data().iter().zip(partial.reconstruct().data()).enumerate() {
                prop_assert!(
                    f64::from((a - b).abs()) <= step_bound,
                    "entry {i}: |{a} - {b}| exceeds half-step bound {step_bound}"
                );
            }

            // Full reconstruction must not exceed the partial one's error
            // (the FP16-rounded factors get a hair of slack).
            let full_err = rel_error(x.data(), c.reconstruct().data());
            let partial_err = rel_error(x.data(), partial.reconstruct().data());
            prop_assert!(
                full_err <= partial_err * 1.02 + 1e-6,
                "low-rank term increased the error: {full_err} > {partial_err}"
            );
            Ok(())
        },
    );
}
