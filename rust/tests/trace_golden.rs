//! Golden tests for the structured trace plane: the *logical* event
//! stream (request lifecycle, sweep policy decisions, flush protocol,
//! GEAR quality records — everything except timing spans) must be
//! bit-identical across `ExecMode::{Sequential, Batched, Pipelined}`,
//! every pool size, and every stage count, including through preemption
//! mid-pipeline. Tracing disabled must cost nothing observable: no
//! events, no ring allocations. And the JSONL journal must round-trip
//! through the schema-validating parser.

use std::sync::Mutex;

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::{FinishReason, GenRequest};
use gear_serve::coordinator::ExecMode;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::trace::export::{parse_json, validate_jsonl};
use gear_serve::trace::{rings_allocated, EventKind};

/// `trace::rings_allocated()` is a process-global monotone counter, so
/// every test in this binary serializes on this lock — a traced test
/// running concurrently with the disabled-mode test would bump the
/// counter mid-delta and fail it spuriously.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking test must not poison the others.
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_model() -> Model {
    let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 160 };
    Model::new(ModelWeights::random(cfg, 11))
}

/// The tight-budget compressed spec from `pool_golden`: a two-token
/// streaming buffer under a 64 KiB budget drives flush-driven growth
/// into the budget mid-sweep, so the run preempts — the trace must hold
/// identical through rollback on every plane.
fn preempt_spec() -> CacheSpec {
    CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 4,
        },
        buffer: 2,
        prefill_rank: 4,
        decode_rank: 4,
    }
}

const BUDGET: usize = 64 << 10;

fn traced_engine(exec: ExecMode, pool: usize, stages: usize) -> Engine {
    let cfg = EngineConfig::new(preempt_spec())
        .with_budget(BUDGET)
        .with_max_batch(16)
        .with_exec(exec)
        .with_pool_threads(pool)
        .with_pipeline_stages(stages)
        .with_trace_capture();
    Engine::new(tiny_model(), cfg)
}

/// Submit the `pool_golden` preemption wave and return the logical
/// event stream.
fn run_logical(e: &mut Engine) -> Vec<EventKind> {
    for i in 0..12u64 {
        let prompt: Vec<u32> = (0..20).map(|t| ((t + i as usize) % 10) as u32 + 3).collect();
        e.submit(GenRequest::greedy(i, prompt, 24));
    }
    let results = e.run_to_completion();
    assert_eq!(results.len(), 12);
    assert!(results.iter().all(|r| r.finish != FinishReason::OutOfMemory));
    e.tracer().expect("trace_capture engine must own a tracer").logical()
}

/// Tentpole determinism contract: the logical stream is a pure function
/// of the request set and policy, never of the execution plane. Pool
/// sizes {1, 4} pin both the inline fallback and real fan-out; stage
/// counts {1, n_layers} pin the degenerate and fully-sharded pipeline —
/// all under active preemption.
#[test]
fn logical_stream_identical_across_planes() {
    let _g = lock();
    let mut seq = traced_engine(ExecMode::Sequential, 1, 1);
    let reference = run_logical(&mut seq);

    // The scenario really exercises every logical family.
    let has = |f: fn(&EventKind) -> bool| reference.iter().any(f);
    assert!(has(|k| matches!(k, EventKind::Enqueue { .. })));
    assert!(has(|k| matches!(k, EventKind::Admit { .. })));
    assert!(has(|k| matches!(k, EventKind::Reserve { .. })));
    assert!(has(|k| matches!(k, EventKind::PrefillChunk { .. })));
    assert!(has(|k| matches!(k, EventKind::DecodeStep { .. })));
    assert!(has(|k| matches!(k, EventKind::FirstToken { .. })));
    assert!(has(|k| matches!(k, EventKind::Seal { .. })));
    assert!(has(|k| matches!(k, EventKind::FlushSubmit { .. })));
    assert!(has(|k| matches!(k, EventKind::FlushJoin { .. })));
    assert!(has(|k| matches!(k, EventKind::Preempt { .. })), "scenario must preempt");
    assert!(has(|k| matches!(k, EventKind::Finish { .. })));
    assert!(has(|k| matches!(k, EventKind::Quality(_))), "GEAR quality records missing");

    for pool in [1, 4] {
        let mut e = traced_engine(ExecMode::Batched, pool, 1);
        assert_eq!(reference, run_logical(&mut e), "batched pool {pool}");
    }
    for stages in [1, 2] {
        // n_layers = 2, so stages = 2 is one layer per stage.
        let mut e = traced_engine(ExecMode::Pipelined, 4, stages);
        assert_eq!(reference, run_logical(&mut e), "pipelined stages {stages}");
    }
}

/// Disabled-mode contract: an untraced engine emits zero events and
/// allocates zero rings — the only cost left on the hot path is the
/// relaxed `tracing_active()` load.
#[test]
fn disabled_run_emits_nothing_and_allocates_no_rings() {
    let _g = lock();
    if std::env::var_os("GEAR_TRACE").is_some() {
        // The engine constructor honours GEAR_TRACE, which would turn
        // this into a traced run; the CI trace job sets it only for
        // engine_e2e, so this is a local-environment escape hatch.
        eprintln!("GEAR_TRACE set; skipping disabled-mode check");
        return;
    }
    let before = rings_allocated();
    let cfg = EngineConfig::new(preempt_spec())
        .with_budget(BUDGET)
        .with_max_batch(16)
        .with_exec(ExecMode::Batched)
        .with_pool_threads(2);
    let mut e = Engine::new(tiny_model(), cfg);
    for i in 0..6u64 {
        let prompt: Vec<u32> = (0..20).map(|t| ((t + i as usize) % 10) as u32 + 3).collect();
        e.submit(GenRequest::greedy(i, prompt, 24));
    }
    assert_eq!(e.run_to_completion().len(), 6);
    assert!(e.tracer().is_none(), "untraced engine must not own a tracer");
    assert!(e.metrics.trace.is_none(), "untraced metrics must carry no summary");
    assert_eq!(
        rings_allocated(),
        before,
        "a disabled run allocated trace rings (worker thread-locals leaked through the gate)"
    );
}

/// Export contract: a traced run writes a Perfetto document whose
/// `traceEvents` carry all three event families, plus a JSONL journal
/// that round-trips through the schema-validating parser.
#[test]
fn jsonl_roundtrips_through_validating_parser() {
    let _g = lock();
    let dir = std::env::temp_dir();
    let path = dir.join(format!("trace_golden_{}.json", std::process::id()));
    let cfg = EngineConfig::new(preempt_spec())
        .with_budget(BUDGET)
        .with_max_batch(16)
        .with_exec(ExecMode::Batched)
        .with_pool_threads(2)
        .with_trace(&path);
    let mut e = Engine::new(tiny_model(), cfg);
    for i in 0..6u64 {
        let prompt: Vec<u32> = (0..20).map(|t| ((t + i as usize) % 10) as u32 + 3).collect();
        e.submit(GenRequest::greedy(i, prompt, 24));
    }
    assert_eq!(e.run_to_completion().len(), 6);

    // Perfetto document: valid JSON, non-empty traceEvents, all three
    // event families (lifecycle, sweep span, quality) present.
    let perfetto = std::fs::read_to_string(&path).expect("perfetto file written");
    let doc = parse_json(&perfetto).expect("perfetto output is valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array present");
    assert!(!events.is_empty());
    let names: Vec<&str> =
        events.iter().filter_map(|e| e.get("name").and_then(|n| n.as_str())).collect();
    assert!(names.iter().any(|n| *n == "admit"), "lifecycle events missing: {names:?}");
    assert!(names.iter().any(|n| n.starts_with("phase:")), "sweep spans missing");
    assert!(names.iter().any(|n| *n == "quality"), "quality events missing");

    // JSONL journal next to it: schema line + one valid line per event.
    let jsonl_path = path.with_extension("jsonl");
    let jsonl = std::fs::read_to_string(&jsonl_path).expect("jsonl journal written");
    let n = validate_jsonl(&jsonl).expect("journal validates against its schema");
    assert!(n > 0, "journal carried no events");
    for family in ["\"kind\":\"admit\"", "\"kind\":\"flush_join\"", "\"kind\":\"quality\""] {
        assert!(jsonl.contains(family), "journal missing {family}");
    }

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&jsonl_path);
}
