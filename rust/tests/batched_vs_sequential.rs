//! Golden equivalence tests for the two-plane engine: the batched executor
//! must be *bit-identical* to the sequential reference — same token
//! streams, same finish reasons, same preemption counts, same peak cache
//! bytes — including through budget-exhaustion preemption mid-sweep.

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::{FinishReason, GenRequest};
use gear_serve::coordinator::ExecMode;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};

/// Everything observable about a finished request, plus run-level memory.
#[derive(Debug, PartialEq)]
struct Outcome {
    results: Vec<(u64, Vec<u32>, FinishReason, usize)>, // id, tokens, finish, preemptions
    peak_cache_bytes: usize,
    requests_preempted: usize,
    requests_oom: usize,
    generated_tokens: usize,
}

fn run(spec: CacheSpec, budget: usize, max_batch: usize, exec: ExecMode, n_reqs: u64) -> Outcome {
    let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 160 };
    let model = Model::new(ModelWeights::random(cfg, 11));
    let mut e = Engine::new(
        model,
        EngineConfig::new(spec).with_budget(budget).with_max_batch(max_batch).with_exec(exec),
    );
    for i in 0..n_reqs {
        let prompt: Vec<u32> = (0..20).map(|t| ((t + i as usize) % 10) as u32 + 3).collect();
        e.submit(GenRequest::greedy(i, prompt, 24));
    }
    let mut results = e.run_to_completion();
    results.sort_by_key(|r| r.id);
    Outcome {
        results: results
            .into_iter()
            .map(|r| (r.id, r.output, r.finish, r.preemptions))
            .collect(),
        peak_cache_bytes: e.metrics.peak_cache_bytes,
        requests_preempted: e.metrics.requests_preempted,
        requests_oom: e.metrics.requests_oom,
        generated_tokens: e.metrics.generated_tokens,
    }
}

#[test]
fn unlimited_budget_bit_identical() {
    for spec in [CacheSpec::Fp16, CacheSpec::gear(4), CacheSpec::parse("kivi-2").unwrap()] {
        let seq = run(spec, usize::MAX, 16, ExecMode::Sequential, 8);
        let bat = run(spec, usize::MAX, 16, ExecMode::Batched, 8);
        assert_eq!(seq, bat, "spec {}", spec.label());
        assert_eq!(seq.results.len(), 8);
    }
}

/// Serialization under a budget that admits one request at a time: FP16's
/// admission estimate covers all growth, so this pins the admission/finish
/// interleaving rather than preemption.
#[test]
fn tight_budget_serialization_bit_identical() {
    let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 160 };
    let full = cfg.fp16_kv_bytes(20 + 24);
    let budget = full + full / 2;

    let seq = run(CacheSpec::Fp16, budget, 8, ExecMode::Sequential, 6);
    let bat = run(CacheSpec::Fp16, budget, 8, ExecMode::Batched, 6);
    assert_eq!(seq, bat);
    assert_eq!(seq.results.len(), 6);
    assert!(seq.results.iter().all(|(_, _, f, _)| *f != FinishReason::OutOfMemory));
    assert!(seq.peak_cache_bytes <= budget);
}

/// A decode-chunk-heavy compressed spec (tiny streaming buffer, high decode
/// rank) whose real bytes overshoot the admission estimate: every buffer
/// flush grows the reservation, and a tight budget makes those adjustments
/// fail mid-sweep — the `preempt_youngest` path, including the commit-loop
/// retry after the active set shifts under it.
fn overhead_heavy_spec() -> CacheSpec {
    CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 4,
        },
        buffer: 2,
        prefill_rank: 4,
        decode_rank: 4,
    }
}

#[test]
fn preemption_path_bit_identical() {
    // ~64 KiB: admits several requests on the analytic estimate, but the
    // per-chunk low-rank/meta overhead drives real bytes well past it, so
    // growth collides and the youngest get preempted and re-admitted.
    let budget = 64 << 10;

    let seq = run(overhead_heavy_spec(), budget, 8, ExecMode::Sequential, 6);
    let bat = run(overhead_heavy_spec(), budget, 8, ExecMode::Batched, 6);
    assert_eq!(seq, bat);

    // The scenario must actually exercise the machinery.
    assert!(seq.requests_preempted > 0, "scenario failed to trigger preemption");
    assert_eq!(seq.results.len(), 6);
    assert!(seq.results.iter().all(|(_, _, f, _)| *f != FinishReason::OutOfMemory));
    assert!(seq.peak_cache_bytes <= budget);
}
