//! Golden equivalence tests for `ExecMode::Hybrid`, the per-sweep
//! plane-selection mode: for *every* switch sequence the policy can
//! produce — across the full pool-size × stage-count × threshold
//! lattice, through preemption, and with flush jobs outstanding at the
//! switch — the hybrid engine must be bit-identical to the sequential
//! reference: same token streams, same finish reasons, same preemption
//! counts, same peak cache bytes, same flush submission schedule.
//!
//! The randomized suites run on the in-repo property framework
//! (`util::prop::forall`): any failure panics with the case index and
//! seed so the exact workload can be replayed.

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::metrics::EngineMetrics;
use gear_serve::coordinator::request::{FinishReason, GenRequest};
use gear_serve::coordinator::ExecMode;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::prop_assert;
use gear_serve::trace::EventKind;
use gear_serve::util::prop::{forall, Config};
use gear_serve::util::rng::Rng;

/// Everything observable about a finished run. `flush_jobs` is part of
/// the contract: the submission schedule is fixed at commit points, so
/// the hybrid plane must submit exactly as many jobs as sequential no
/// matter which plane executed each sweep.
#[derive(Debug, PartialEq)]
struct Outcome {
    results: Vec<(u64, Vec<u32>, FinishReason, usize)>, // id, tokens, finish, preemptions
    peak_cache_bytes: usize,
    requests_preempted: usize,
    requests_oom: usize,
    generated_tokens: usize,
    flush_jobs: usize,
}

/// Four layers so the stage lattice {1, 2, n_layers} is non-degenerate:
/// stages 2 puts two layers per stage, stages 4 one per stage.
fn deep_model() -> Model {
    let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 4, n_heads: 2, max_seq: 160 };
    Model::new(ModelWeights::random(cfg, 11))
}

/// Compressed spec whose streaming buffer seals every `buffer` decoded
/// tokens — `buffer: 1` keeps a flush job outstanding across every
/// sweep boundary, including sweeps where the plane switches.
fn gearl_spec(buffer: usize) -> CacheSpec {
    CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 4,
        },
        buffer,
        prefill_rank: 4,
        decode_rank: 4,
    }
}

/// One randomized workload: request count, per-request prompt lengths
/// and decode lengths (staggered lengths make the decode batch decay
/// through the threshold), cache budget (the tight settings force
/// preemption), streaming-buffer size, and the hybrid threshold itself.
#[derive(Debug, Clone)]
struct Workload {
    prompt_lens: Vec<usize>,
    max_new: Vec<usize>,
    budget: usize,
    buffer: usize,
    threshold: usize,
}

fn gen_workload(r: &mut Rng) -> Workload {
    let n = 1 + r.next_below(12) as usize; // 1..=12: crosses MIN_FANOUT = 8
    let prompt_lens = (0..n).map(|_| 4 + r.next_below(28) as usize).collect();
    let max_new = (0..n).map(|_| 2 + r.next_below(14) as usize).collect();
    // usize::MAX never preempts; 64 KiB collides with flush-driven
    // growth mid-sweep (the pool_golden preemption regime); 96 KiB sits
    // in between and preempts only the largest workloads.
    let budget = *r.choose(&[usize::MAX, 64 << 10, 96 << 10]);
    let buffer = *r.choose(&[1, 2]);
    let threshold = 1 + r.next_below(12) as usize; // 1..=12 straddles every batch
    Workload { prompt_lens, max_new, budget, buffer, threshold }
}

/// Run `w` to completion on one engine configuration. Prompt contents
/// are a pure function of (request index, prompt length), so sequential
/// and hybrid runs see byte-identical inputs.
fn run(w: &Workload, exec: ExecMode, pool: usize, stages: usize) -> (Outcome, EngineMetrics) {
    let mut cfg = EngineConfig::new(gearl_spec(w.buffer))
        .with_budget(w.budget)
        .with_max_batch(16)
        .with_exec(exec);
    if exec != ExecMode::Sequential {
        cfg = cfg
            .with_pool_threads(pool)
            .with_pipeline_stages(stages)
            .with_hybrid_threshold(w.threshold);
    }
    let mut e = Engine::new(deep_model(), cfg);
    for (i, (&len, &max_new)) in w.prompt_lens.iter().zip(&w.max_new).enumerate() {
        let prompt: Vec<u32> = (0..len).map(|t| ((t + i) % 10) as u32 + 3).collect();
        e.submit(GenRequest::greedy(i as u64, prompt, max_new));
    }
    let mut results = e.run_to_completion();
    results.sort_by_key(|r| r.id);
    assert_eq!(e.budget_used(), 0, "bytes still reserved after the run drained");
    let out = Outcome {
        results: results
            .into_iter()
            .map(|r| (r.id, r.output, r.finish, r.preemptions))
            .collect(),
        peak_cache_bytes: e.metrics.peak_cache_bytes,
        requests_preempted: e.metrics.requests_preempted,
        requests_oom: e.metrics.requests_oom,
        generated_tokens: e.metrics.generated_tokens,
        flush_jobs: e.metrics.flush_jobs,
    };
    (out, e.metrics.clone())
}

/// The property: at one pool size, for every stage count in {1, 2,
/// n_layers} the hybrid engine reproduces the sequential reference
/// bit-for-bit on a randomized workload, and the per-plane sweep
/// counters account for every decode sweep consistently.
fn hybrid_matches_sequential_at_pool(pool: usize, seed: u64) {
    forall(
        Config { cases: 64, seed },
        gen_workload,
        |w| {
            let (reference, _) = run(w, ExecMode::Sequential, 1, 1);
            prop_assert!(
                reference.results.len() == w.prompt_lens.len(),
                "sequential reference lost requests: {} of {}",
                reference.results.len(),
                w.prompt_lens.len()
            );
            if w.budget < usize::MAX {
                prop_assert!(
                    reference.peak_cache_bytes <= w.budget,
                    "sequential peak {} overshot budget {}",
                    reference.peak_cache_bytes,
                    w.budget
                );
            }
            for stages in [1, 2, 4] {
                let (got, m) = run(w, ExecMode::Hybrid, pool, stages);
                prop_assert!(
                    reference == got,
                    "pool {pool} stages {stages} diverged from sequential:\n  ref: {reference:?}\n  got: {got:?}"
                );
                // Every decode sweep went through exactly one plane, and
                // the switch count can't exceed the sweep count.
                let sweeps = m.hybrid_batched_sweeps + m.hybrid_pipelined_sweeps;
                prop_assert!(sweeps > 0, "pool {pool} stages {stages}: no hybrid sweeps recorded");
                prop_assert!(
                    m.hybrid_switches < sweeps,
                    "pool {pool} stages {stages}: {} switches in {sweeps} sweeps",
                    m.hybrid_switches
                );
            }
            Ok(())
        },
    );
}

#[test]
fn hybrid_matches_sequential_pool_1() {
    hybrid_matches_sequential_at_pool(1, 0x6EA2_0001);
}

#[test]
fn hybrid_matches_sequential_pool_2() {
    hybrid_matches_sequential_at_pool(2, 0x6EA2_0002);
}

#[test]
fn hybrid_matches_sequential_pool_4() {
    hybrid_matches_sequential_at_pool(4, 0x6EA2_0004);
}

/// Staggered decode lengths: request `i` decodes `4 + 2 i` tokens, so
/// the decode batch decays one request at a time through any threshold
/// in range — the deterministic way to force plane switches.
fn staggered(n: usize, budget: usize, buffer: usize, threshold: usize) -> Workload {
    Workload {
        prompt_lens: vec![20; n],
        max_new: (0..n).map(|i| 4 + 2 * i).collect(),
        budget,
        buffer,
        threshold,
    }
}

/// Torture: a one-token streaming buffer keeps a compression job
/// outstanding across *every* sweep boundary, so the plane switch
/// happens with flushes submitted by the other plane still in flight —
/// the join at the next commit must observe them regardless of which
/// plane runs that sweep. Tight budget adds preemption churn on top.
#[test]
fn switch_with_flush_outstanding_bit_identical() {
    let w = staggered(12, 64 << 10, 1, 6);
    let (reference, ref_m) = run(&w, ExecMode::Sequential, 1, 1);
    assert!(ref_m.flush_jobs > 0, "one-token buffers produced no flush jobs");

    let (got, m) = run(&w, ExecMode::Hybrid, 4, 2);
    assert_eq!(reference, got);
    assert!(m.hybrid_switches >= 1, "decaying batch never crossed threshold 6");
    assert!(m.hybrid_batched_sweeps > 0, "batched plane never ran");
    assert!(m.hybrid_pipelined_sweeps > 0, "pipelined plane never ran");
}

/// Torture: preemption and plane switching in the same run — the tight
/// budget preempts the youngest requests while the decaying batch
/// drives switches, and readmission swings the batch back up across the
/// threshold. Victim schedule, readmission interleaving, and token
/// streams must all match sequential.
#[test]
fn preemption_straddling_switches_bit_identical() {
    let w = staggered(12, 64 << 10, 2, 6);
    let (reference, _) = run(&w, ExecMode::Sequential, 1, 1);
    assert!(reference.requests_preempted > 0, "scenario failed to trigger preemption");
    assert!(reference.results.iter().all(|(_, _, f, _)| *f != FinishReason::OutOfMemory));

    for (pool, stages) in [(2, 2), (4, 4)] {
        let (got, m) = run(&w, ExecMode::Hybrid, pool, stages);
        assert_eq!(reference, got, "pool {pool} stages {stages}");
        assert!(m.hybrid_switches >= 1, "pool {pool} stages {stages}: no switch under preemption");
    }
}

/// Hysteresis: a monotonically decaying batch crosses the threshold
/// downward exactly once, so the policy must switch exactly once — no
/// flapping at the boundary. Unbounded budget keeps readmission churn
/// out so the batch really is monotone.
#[test]
fn hysteresis_switches_once_per_crossing() {
    let w = staggered(10, usize::MAX, 2, 5);
    let (reference, _) = run(&w, ExecMode::Sequential, 1, 1);
    let (got, m) = run(&w, ExecMode::Hybrid, 4, 2);
    assert_eq!(reference, got);
    assert!(m.hybrid_batched_sweeps > 0, "batch of 10 should start on the batched plane");
    assert!(m.hybrid_pipelined_sweeps > 0, "decayed batch should end on the pipelined plane");
    assert_eq!(m.hybrid_switches, 1, "monotone decay must switch exactly once");
}

/// Threshold extremes pin each plane: threshold 1 means every non-empty
/// batch is `>= 1`, so the policy always picks batched; a threshold no
/// batch can reach means it always picks pipelined. Either way: zero
/// switches, and still bit-identical to sequential.
#[test]
fn threshold_extremes_pin_one_plane() {
    let w = staggered(10, usize::MAX, 2, 1);
    let (reference, _) = run(&w, ExecMode::Sequential, 1, 1);

    let (got, m) = run(&w, ExecMode::Hybrid, 4, 2);
    assert_eq!(reference, got, "threshold 1");
    assert_eq!(m.hybrid_pipelined_sweeps, 0, "threshold 1 must never pipeline");
    assert_eq!(m.hybrid_switches, 0);

    let w = Workload { threshold: usize::MAX, ..w };
    let (got, m) = run(&w, ExecMode::Hybrid, 4, 2);
    assert_eq!(reference, got, "threshold usize::MAX");
    assert_eq!(m.hybrid_batched_sweeps, 0, "unreachable threshold must always pipeline");
    assert_eq!(m.hybrid_switches, 0);
}

/// Trace contract: the hybrid logical stream is the sequential logical
/// stream plus one `plane_chosen` record per decode sweep — filtering
/// those out must give bit-identical streams, each `plane_chosen`'s
/// deciding batch size must match the `decode_step` it precedes, and
/// the chosen sequence must actually visit both planes (while the run,
/// by stream equality, still preempts exactly like sequential).
#[test]
fn logical_stream_matches_sequential_modulo_plane_chosen() {
    let w = staggered(12, 64 << 10, 2, 6);
    let mk = |exec: ExecMode| {
        let mut cfg = EngineConfig::new(gearl_spec(w.buffer))
            .with_budget(w.budget)
            .with_max_batch(16)
            .with_exec(exec)
            .with_trace_capture();
        if exec == ExecMode::Hybrid {
            cfg = cfg
                .with_pool_threads(4)
                .with_pipeline_stages(2)
                .with_hybrid_threshold(w.threshold);
        }
        let mut e = Engine::new(deep_model(), cfg);
        for (i, (&len, &max_new)) in w.prompt_lens.iter().zip(&w.max_new).enumerate() {
            let prompt: Vec<u32> = (0..len).map(|t| ((t + i) % 10) as u32 + 3).collect();
            e.submit(GenRequest::greedy(i as u64, prompt, max_new));
        }
        e.run_to_completion();
        e.tracer().expect("trace_capture engine must own a tracer").logical()
    };

    let reference = mk(ExecMode::Sequential);
    assert!(reference.iter().any(|k| matches!(k, EventKind::Preempt { .. })));
    assert!(!reference.iter().any(|k| matches!(k, EventKind::PlaneChosen { .. })));

    let hybrid = mk(ExecMode::Hybrid);
    let filtered: Vec<&EventKind> = hybrid
        .iter()
        .filter(|k| !matches!(k, EventKind::PlaneChosen { .. }))
        .collect();
    assert_eq!(reference.iter().collect::<Vec<_>>(), filtered);

    // One plane_chosen per decode sweep, immediately before its
    // decode_step, with matching batch size.
    let mut chosen = 0usize;
    for pair in hybrid.windows(2) {
        if let EventKind::PlaneChosen { batch, .. } = &pair[0] {
            chosen += 1;
            match &pair[1] {
                EventKind::DecodeStep { n_seqs } => assert_eq!(batch, n_seqs),
                other => panic!("plane_chosen not followed by decode_step: {other:?}"),
            }
        }
    }
    let steps =
        hybrid.iter().filter(|k| matches!(k, EventKind::DecodeStep { .. })).count();
    assert_eq!(chosen, steps, "one plane_chosen per decode sweep");

    // The chosen sequence really visits both planes — the scenario is a
    // switch sequence, not a constant plane relabelled.
    let flags: Vec<bool> = hybrid
        .iter()
        .filter_map(|k| match k {
            EventKind::PlaneChosen { pipelined, .. } => Some(*pipelined),
            _ => None,
        })
        .collect();
    assert!(
        flags.windows(2).any(|p| p[0] != p[1]),
        "decaying batch under threshold 6 must switch planes"
    );
}
