//! Golden tests for the chunked-prefill plane.
//!
//! 1. The engine's token streams are **bit-identical** for every
//!    `prefill_chunk` value (chunked prefill attends against exact f32 K/V
//!    and commits through the same one-shot ingest as whole-prompt
//!    prefill), in both execution modes.
//! 2. Preempting a request mid-prefill rolls back cleanly: the request
//!    recomputes from scratch, produces the same tokens it would have with
//!    an unlimited budget, and every reserved byte drains by the end.

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::{FinishReason, GenRequest};
use gear_serve::coordinator::ExecMode;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};

fn test_config() -> ModelConfig {
    ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 256 }
}

/// Mixed-length prompts so chunk boundaries land everywhere.
fn submit_mixed(e: &mut Engine, n_reqs: u64) {
    for i in 0..n_reqs {
        let len = 5 + (i as usize * 11) % 40;
        let prompt: Vec<u32> = (0..len).map(|t| ((t + i as usize) % 10) as u32 + 3).collect();
        e.submit(GenRequest::greedy(i, prompt, 12));
    }
}

type Outcome = Vec<(u64, Vec<u32>, FinishReason, usize)>;

fn run(spec: CacheSpec, budget: usize, chunk: usize, exec: ExecMode) -> Outcome {
    let model = Model::new(ModelWeights::random(test_config(), 11));
    let mut e = Engine::new(
        model,
        EngineConfig::new(spec)
            .with_budget(budget)
            .with_max_batch(8)
            .with_exec(exec)
            .with_prefill_chunk(chunk),
    );
    submit_mixed(&mut e, 8);
    let mut results = e.run_to_completion();
    assert_eq!(e.budget_used(), 0, "reservations must drain (chunk {chunk})");
    results.sort_by_key(|r| r.id);
    results.into_iter().map(|r| (r.id, r.output, r.finish, r.preemptions)).collect()
}

#[test]
fn chunked_prefill_streams_bit_identical_across_chunk_sizes() {
    for spec in [CacheSpec::Fp16, CacheSpec::gear(4), CacheSpec::parse("kivi-2").unwrap()] {
        let whole = run(spec, usize::MAX, usize::MAX, ExecMode::Batched);
        for chunk in [1usize, 3, 16, 128] {
            for exec in [ExecMode::Sequential, ExecMode::Batched] {
                let chunked = run(spec, usize::MAX, chunk, exec);
                assert_eq!(chunked, whole, "chunk {} {:?} spec {}", chunk, exec, spec.label());
            }
        }
    }
}

#[test]
fn chunked_prefill_tight_budget_bit_identical() {
    // FP16's admission estimate covers all growth, so a serializing budget
    // is deterministic; the token streams must not depend on chunking.
    let cfg = test_config();
    let budget = cfg.fp16_kv_bytes(44 + 12) + cfg.fp16_kv_bytes(20);
    let whole = run(CacheSpec::Fp16, budget, usize::MAX, ExecMode::Batched);
    for chunk in [4usize, 32] {
        assert_eq!(run(CacheSpec::Fp16, budget, chunk, ExecMode::Batched), whole, "chunk {chunk}");
    }
    assert!(whole.iter().all(|(_, _, f, _)| *f != FinishReason::OutOfMemory));
}

/// Overhead-heavy compressed spec: real bytes (and the FP16-accounted
/// prefill transient) run well past the admission estimate, so a tight
/// budget forces preemption of the younger, still-prefilling request.
fn spec_for_preemption() -> CacheSpec {
    CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 2,
        },
        buffer: 4,
        prefill_rank: 2,
        decode_rank: 2,
    }
}

#[test]
fn preemption_mid_prefill_recomputes_from_scratch() {
    let cfg = test_config();
    let model = || Model::new(ModelWeights::random(cfg, 11));
    let short = GenRequest::greedy(0, vec![3, 4, 5, 6, 7, 8, 9, 10], 16);
    let long_prompt: Vec<u32> = (0..96).map(|t| (t % 10) as u32 + 3).collect();
    let long = GenRequest::greedy(1, long_prompt.clone(), 4);

    // Reference: unlimited budget, no preemption possible.
    let reference = {
        let mut e = Engine::new(
            model(),
            EngineConfig::new(spec_for_preemption()).with_max_batch(4).with_prefill_chunk(16),
        );
        e.submit(short.clone());
        e.submit(long.clone());
        let mut res = e.run_to_completion();
        assert_eq!(e.metrics.requests_preempted, 0);
        res.sort_by_key(|r| r.id);
        res
    };

    // Tight budget: exactly the long request's peak in-flight prefill
    // bytes. Both admit (compressed estimates are small), but mid-prefill
    // the long request's FP16-accounted transient no longer fits next to
    // the short one — the younger long request is preempted with a
    // half-finished prefill, recomputes from scratch, and must still
    // produce identical tokens.
    let budget = cfg.fp16_kv_bytes(long_prompt.len());
    let mut e = Engine::new(
        model(),
        EngineConfig::new(spec_for_preemption())
            .with_budget(budget)
            .with_max_batch(4)
            .with_prefill_chunk(16),
    );
    e.submit(short);
    e.submit(long);
    let mut res = e.run_to_completion();
    res.sort_by_key(|r| r.id);

    assert!(e.metrics.requests_preempted > 0, "scenario must preempt mid-prefill");
    assert_eq!(res.len(), 2);
    assert!(res.iter().all(|r| r.finish != FinishReason::OutOfMemory));
    assert!(res[1].preemptions > 0, "long request must have been preempted");
    for (r, want) in res.iter().zip(&reference) {
        assert_eq!(r.output, want.output, "request {} diverged after recompute", r.id);
        assert_eq!(r.finish, want.finish);
    }

    // Byte accounting: every reservation (steady + headroom) drained, and
    // the pre-reserve phase kept the peak within the budget.
    assert_eq!(e.budget_used(), 0);
    assert!(e.metrics.peak_cache_bytes <= budget, "{} > {budget}", e.metrics.peak_cache_bytes);
}
