//! Pool lifecycle: engines own their worker pools. This lives in its own
//! test binary (one test) because [`live_pool_workers`] is process-global —
//! engines created by concurrently-running tests in the same binary would
//! make the count flaky.

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::executor::live_pool_workers;
use gear_serve::coordinator::request::GenRequest;
use gear_serve::coordinator::ExecMode;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};

fn tiny_model() -> Model {
    let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 160 };
    Model::new(ModelWeights::random(cfg, 11))
}

/// A `Sequential` engine spawns no threads; a `Batched` engine spawns
/// exactly its configured pool, keeps the same workers alive across runs
/// (persistent pool — no per-sweep spawning), and joins all of them on
/// drop. `WorkerPool::drop` joins synchronously and each worker decrements
/// the live count before exiting, so no polling is needed.
#[test]
fn engine_owns_and_joins_its_pool() {
    let before = live_pool_workers();

    let seq = Engine::new(
        tiny_model(),
        EngineConfig::new(CacheSpec::gear(4)).with_exec(ExecMode::Sequential),
    );
    assert_eq!(live_pool_workers(), before, "Sequential mode must not spawn pool threads");
    drop(seq);

    let mut e = Engine::new(
        tiny_model(),
        EngineConfig::new(CacheSpec::gear(4))
            .with_exec(ExecMode::Batched)
            .with_max_batch(16)
            .with_pool_threads(3),
    );
    assert_eq!(live_pool_workers(), before + 3, "pool spawns once, at engine construction");

    // Two full generation waves through the same engine: the pool is
    // reused, not respawned — the live count never moves.
    for wave in 0..2u64 {
        let prompt: Vec<u32> = (0..20).map(|t| (t % 10) as u32 + 3).collect();
        for i in 0..12u64 {
            e.submit(GenRequest::greedy(wave * 100 + i, prompt.clone(), 16));
        }
        let results = e.run_to_completion();
        assert_eq!(results.len(), 12);
        assert_eq!(live_pool_workers(), before + 3, "wave {wave} changed the worker count");
    }

    drop(e);
    assert_eq!(live_pool_workers(), before, "engine drop must join every pool worker");
}
