//! Golden equivalence tests for the parallel execution planes: every pool
//! size of `ExecMode::Batched` *and* every stage count of
//! `ExecMode::Pipelined` must be *bit-identical* to the sequential
//! reference — same token streams, same finish reasons, same preemption
//! counts, same peak cache bytes — including through preemption, across
//! many reuses of one pool, and with worker-side component timings folded
//! back into the engine's breakdown.

use std::time::Duration;

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::{FinishReason, GenRequest};
use gear_serve::coordinator::ExecMode;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};

/// Everything observable about a finished request, plus run-level memory.
#[derive(Debug, PartialEq)]
struct Outcome {
    results: Vec<(u64, Vec<u32>, FinishReason, usize)>, // id, tokens, finish, preemptions
    peak_cache_bytes: usize,
    requests_preempted: usize,
    requests_oom: usize,
    generated_tokens: usize,
}

fn tiny_model() -> Model {
    let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 160 };
    Model::new(ModelWeights::random(cfg, 11))
}

fn make_engine(spec: CacheSpec, budget: usize, exec: ExecMode, pool: Option<usize>) -> Engine {
    let mut cfg = EngineConfig::new(spec).with_budget(budget).with_max_batch(16).with_exec(exec);
    if let Some(p) = pool {
        cfg = cfg.with_pool_threads(p);
    }
    Engine::new(tiny_model(), cfg)
}

/// Four layers so stage partitioning is non-trivial: stages {1, 2, 4} give
/// layer ranges {[0,4)}, {[0,2) [2,4)}, and one layer per stage.
fn deep_model() -> Model {
    let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 4, n_heads: 2, max_seq: 160 };
    Model::new(ModelWeights::random(cfg, 11))
}

fn make_pipelined(spec: CacheSpec, budget: usize, stages: usize) -> Engine {
    let cfg = EngineConfig::new(spec)
        .with_budget(budget)
        .with_max_batch(16)
        .with_exec(ExecMode::Pipelined)
        .with_pool_threads(4)
        .with_pipeline_stages(stages);
    Engine::new(tiny_model(), cfg)
}

/// Submit one wave of requests (ids offset by `wave * 100` so waves stay
/// distinguishable) and run it to completion.
fn run_wave(e: &mut Engine, wave: u64, n_reqs: u64) -> Outcome {
    for i in 0..n_reqs {
        let prompt: Vec<u32> = (0..20).map(|t| ((t + i as usize) % 10) as u32 + 3).collect();
        e.submit(GenRequest::greedy(wave * 100 + i, prompt, 24));
    }
    let mut results = e.run_to_completion();
    results.sort_by_key(|r| r.id);
    Outcome {
        results: results
            .into_iter()
            .map(|r| (r.id, r.output, r.finish, r.preemptions))
            .collect(),
        peak_cache_bytes: e.metrics.peak_cache_bytes,
        requests_preempted: e.metrics.requests_preempted,
        requests_oom: e.metrics.requests_oom,
        generated_tokens: e.metrics.generated_tokens,
    }
}

fn host_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Pool sizes 1, 2, and host parallelism all reproduce the sequential
/// reference exactly. 12 requests at max_batch 16 keeps the decode batch
/// above the executor's inline-fanout threshold, so the pool dispatch path
/// (not the inline fallback) is what's being pinned.
#[test]
fn pool_sizes_bit_identical() {
    for spec in [CacheSpec::Fp16, CacheSpec::gear(4), CacheSpec::parse("kivi-2").unwrap()] {
        let mut seq = make_engine(spec, usize::MAX, ExecMode::Sequential, None);
        let reference = run_wave(&mut seq, 0, 12);
        assert_eq!(reference.results.len(), 12);
        for pool in [1, 2, host_parallelism()] {
            let mut e = make_engine(spec, usize::MAX, ExecMode::Batched, Some(pool));
            let got = run_wave(&mut e, 0, 12);
            assert_eq!(reference, got, "spec {} pool {pool}", spec.label());
        }
    }
}

/// A decode-chunk-heavy compressed spec (tiny streaming buffer, high decode
/// rank) under a tight budget: flush-driven growth collides with the budget
/// mid-sweep and the youngest requests get preempted. The pool must
/// reproduce the preemption/readmission interleaving token-for-token.
#[test]
fn preemption_under_pool_bit_identical() {
    let spec = CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 4,
        },
        buffer: 2,
        prefill_rank: 4,
        decode_rank: 4,
    };
    let budget = 64 << 10;

    let mut seq = make_engine(spec, budget, ExecMode::Sequential, None);
    let reference = run_wave(&mut seq, 0, 12);
    assert!(reference.requests_preempted > 0, "scenario failed to trigger preemption");
    assert!(reference.results.iter().all(|(_, _, f, _)| *f != FinishReason::OutOfMemory));
    assert!(reference.peak_cache_bytes <= budget);

    for pool in [2, host_parallelism()] {
        let mut e = make_engine(spec, budget, ExecMode::Batched, Some(pool));
        let got = run_wave(&mut e, 0, 12);
        assert_eq!(reference, got, "pool {pool}");
    }
}

/// The async-flush torture case: a one-token streaming buffer seals every
/// decode step, so a compression job is outstanding across *every* sweep
/// boundary — submitted at one commit, overlapping the next sweep's
/// prefill/decode, joined at the next commit. Under a tight budget the
/// sealed requests also get preempted with those flushes still in flight
/// (tickets dropped, results abandoned) and later re-admitted from scratch.
/// Token streams, preemption schedule, peak bytes, *and* the submitted job
/// count must still be bit-identical to the blocking sequential reference
/// at every pool size: join points are fixed by data dependence, not by
/// when a worker happens to finish.
#[test]
fn flush_outstanding_across_sweeps_bit_identical() {
    let spec = CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 4,
        },
        buffer: 1, // seal on every decode step
        prefill_rank: 4,
        decode_rank: 4,
    };
    let budget = 64 << 10;

    let mut seq = make_engine(spec, budget, ExecMode::Sequential, None);
    let reference = run_wave(&mut seq, 0, 12);
    let ref_flush_jobs = seq.metrics.flush_jobs;
    assert!(reference.requests_preempted > 0, "scenario failed to trigger preemption");
    assert!(reference.results.iter().all(|(_, _, f, _)| *f != FinishReason::OutOfMemory));
    assert!(reference.peak_cache_bytes <= budget);
    assert!(ref_flush_jobs > 0, "one-token buffers produced no flush jobs");

    for pool in [1, 2, host_parallelism()] {
        let mut e = make_engine(spec, budget, ExecMode::Batched, Some(pool));
        let got = run_wave(&mut e, 0, 12);
        assert_eq!(reference, got, "pool {pool}");
        assert_eq!(
            e.metrics.flush_jobs, ref_flush_jobs,
            "pool {pool}: flush submission schedule diverged from sequential"
        );
    }
}

/// One engine, many waves: the pool's pinned per-worker scratch and the
/// engine's pooled logits vectors are reused across
/// `run_to_completion` calls, and every wave still matches a fresh
/// sequential engine exactly — buffer reuse cannot leak state between
/// sweeps or waves.
#[test]
fn pool_reuse_across_waves_bit_identical() {
    let spec = CacheSpec::gear(4);
    let mut pooled = make_engine(spec, usize::MAX, ExecMode::Batched, Some(2));
    for wave in 0..3u64 {
        // Fresh sequential engine per wave: its metrics then describe only
        // this wave, matching the pooled engine's per-wave counters is not
        // possible for cumulative fields, so compare against a fresh
        // reference and only the per-wave token streams + finishes.
        let mut seq = make_engine(spec, usize::MAX, ExecMode::Sequential, None);
        let reference = run_wave(&mut seq, wave, 10);
        let got = run_wave(&mut pooled, wave, 10);
        assert_eq!(reference.results, got.results, "wave {wave}");
        assert_eq!(got.results.len(), 10);
    }
}

/// GEAR component timings recorded on pool workers (deferred flush
/// compression) fold back into the engine's Fig-3a breakdown: a pooled
/// compressed run must report nonzero quant time just like a sequential
/// one, and the flush bookkeeping must show the deferred jobs ran.
#[test]
fn worker_timings_fold_back() {
    let spec = CacheSpec::gear(4);
    let mut e = make_engine(spec, usize::MAX, ExecMode::Batched, Some(2));
    let out = run_wave(&mut e, 0, 12);
    assert_eq!(out.results.len(), 12);
    assert!(
        e.metrics.phases.get("quant") > Duration::ZERO,
        "quant time from pool workers missing from the engine breakdown: {:?}",
        e.metrics.phases
    );
    assert!(e.metrics.flush_jobs > 0, "compressed decode run produced no deferred flushes");
    assert!(!e.metrics.step_latencies.is_empty(), "decode sweeps recorded no step latencies");
    assert!(e.metrics.step_p99() >= e.metrics.step_p50());
}

/// The pipeline plane at stage counts {1, 2, n_layers} reproduces the
/// sequential reference exactly, for FP16 and both compressed specs. The
/// tiny model has n_layers = 2, so stages = 2 is the one-layer-per-stage
/// extreme; stages = 1 exercises the degenerate inline fallback.
#[test]
fn pipelined_stages_bit_identical() {
    for spec in [CacheSpec::Fp16, CacheSpec::gear(4), CacheSpec::parse("kivi-2").unwrap()] {
        let mut seq = make_engine(spec, usize::MAX, ExecMode::Sequential, None);
        let reference = run_wave(&mut seq, 0, 12);
        assert_eq!(reference.results.len(), 12);
        for stages in [1, 2] {
            let mut e = make_pipelined(spec, usize::MAX, stages);
            let got = run_wave(&mut e, 0, 12);
            assert_eq!(reference, got, "spec {} stages {stages}", spec.label());
        }
    }
}

/// Batch = 1 is the case the pipeline plane exists for — the batch plane's
/// MIN_FANOUT gate runs it inline, the pipeline still spreads the layers
/// across workers. A deeper 4-layer model pins the non-trivial partitions
/// (stages 2 → two layers per stage) and the stage-count clamp (stages 8 →
/// n_layers), and checks the per-stage timing plumbing fills one slot per
/// stage.
#[test]
fn pipelined_batch_of_one_bit_identical() {
    let spec = CacheSpec::gear(4);
    let mk = |exec: ExecMode, stages: usize| {
        let mut cfg = EngineConfig::new(spec).with_max_batch(16).with_exec(exec);
        if exec == ExecMode::Pipelined {
            cfg = cfg.with_pool_threads(4).with_pipeline_stages(stages);
        }
        Engine::new(deep_model(), cfg)
    };
    let mut seq = mk(ExecMode::Sequential, 1);
    let reference = run_wave(&mut seq, 0, 1);
    assert_eq!(reference.results.len(), 1);
    for stages in [1, 2, 4, 8] {
        let mut e = mk(ExecMode::Pipelined, stages);
        let got = run_wave(&mut e, 0, 1);
        assert_eq!(reference, got, "stages {stages}");
        if stages >= 2 {
            let expect = stages.min(4); // clamped to n_layers
            assert_eq!(
                e.metrics.stage_busy.len(),
                expect,
                "stages {stages}: stage timing slots"
            );
            let occ = e.metrics.stage_occupancy();
            assert!(occ.iter().all(|&o| (0.0..=1.0).contains(&o)), "occupancy {occ:?}");
        }
    }
}

/// Preemption under pipelining: the same tight-budget scenario that pins
/// the batch plane's preemption interleaving must also hold stage-for-stage
/// — mid-pipeline preemption rolls back through the identical commit
/// points, so the victim schedule and every survivor's tokens match the
/// sequential reference bit-for-bit.
#[test]
fn preemption_under_pipeline_bit_identical() {
    let spec = CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 4,
        },
        buffer: 2,
        prefill_rank: 4,
        decode_rank: 4,
    };
    let budget = 64 << 10;

    let mut seq = make_engine(spec, budget, ExecMode::Sequential, None);
    let reference = run_wave(&mut seq, 0, 12);
    assert!(reference.requests_preempted > 0, "scenario failed to trigger preemption");

    for stages in [1, 2] {
        let mut e = make_pipelined(spec, budget, stages);
        let got = run_wave(&mut e, 0, 12);
        assert_eq!(reference, got, "stages {stages}");
    }
}

/// The flush torture case on the pipeline plane: one-token buffers keep a
/// compression job outstanding across every sweep, and non-final stages
/// drain their own layers' jobs between passes. The submission schedule is
/// fixed at commit points, so the job *count* — like everything else —
/// must match the blocking sequential reference.
#[test]
fn pipelined_flush_locality_bit_identical() {
    let spec = CacheSpec::Compressed {
        method: gear_serve::gear::Method::GearL {
            bits: 2,
            backbone: gear_serve::gear::compose::Backbone::Kivi(16),
            r: 4,
        },
        buffer: 1, // seal on every decode step
        prefill_rank: 4,
        decode_rank: 4,
    };
    let budget = 64 << 10;

    let mut seq = make_engine(spec, budget, ExecMode::Sequential, None);
    let reference = run_wave(&mut seq, 0, 12);
    let ref_flush_jobs = seq.metrics.flush_jobs;
    assert!(ref_flush_jobs > 0, "one-token buffers produced no flush jobs");

    for stages in [1, 2] {
        let mut e = make_pipelined(spec, budget, stages);
        let got = run_wave(&mut e, 0, 12);
        assert_eq!(reference, got, "stages {stages}");
        assert_eq!(
            e.metrics.flush_jobs, ref_flush_jobs,
            "stages {stages}: flush submission schedule diverged from sequential"
        );
    }
}
