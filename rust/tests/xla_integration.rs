//! XLA-backend integration: load the AOT artifacts on the PJRT CPU client,
//! run generation, and cross-validate against the pure-Rust forward.
//! All tests skip when artifacts are absent. The whole suite is gated on
//! the `xla` cargo feature (PJRT runtime needs the vendored `xla` crate).
#![cfg(feature = "xla")]

use gear_serve::kvcache::{CacheSpec, RequestCache};
use gear_serve::model::config::Tokenizer;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::runtime::xla_model::XlaModel;

fn ready() -> bool {
    if !Artifacts::available() {
        eprintln!("skipping: artifacts not built");
        return false;
    }
    true
}

#[test]
fn xla_prefill_matches_rust_forward() {
    if !ready() {
        return;
    }
    let xm = XlaModel::load_default().unwrap();
    let w = ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap();
    let model = Model::new(w);
    let tok = Tokenizer::new();
    let prompt = tok.encode_with_bos("a=3;b=7;c=a+b;c?\n");

    let (xla_logits, _st) = xm.prefill(&prompt, 128).unwrap();

    let c = model.config();
    let mut cache = RequestCache::new(&CacheSpec::Fp16, c.n_layers, c.d_model, c.n_heads);
    let rust = model.prefill(&prompt, &mut cache);

    let mut worst = 0f32;
    for (a, b) in xla_logits.iter().zip(&rust.last_logits) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 0.08, "xla vs rust logits: max diff {worst}");
    assert_eq!(
        gear_serve::model::sampler::argmax(&xla_logits),
        gear_serve::model::sampler::argmax(&rust.last_logits)
    );
}

#[test]
fn xla_decode_steps_match_rust() {
    if !ready() {
        return;
    }
    let xm = XlaModel::load_default().unwrap();
    let w = ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap();
    let model = Model::new(w);
    let tok = Tokenizer::new();
    let prompt = tok.encode_with_bos("k1=5;k2=9;k1?\n");

    let (mut xla_logits, mut st) = xm.prefill(&prompt, 128).unwrap();
    let c = model.config();
    let mut cache = RequestCache::new(&CacheSpec::Fp16, c.n_layers, c.d_model, c.n_heads);
    let mut rust_logits = model.prefill(&prompt, &mut cache).last_logits;

    for step in 0..6 {
        let nxt_x = gear_serve::model::sampler::argmax(&xla_logits);
        let nxt_r = gear_serve::model::sampler::argmax(&rust_logits);
        assert_eq!(nxt_x, nxt_r, "divergence at step {step}");
        let pos = prompt.len() + step;
        xla_logits = xm.decode(nxt_x, pos, &mut st).unwrap();
        rust_logits = model.decode_step(nxt_r, pos, &mut cache);
    }
}

#[test]
fn xla_generation_end_to_end() {
    if !ready() {
        return;
    }
    let xm = XlaModel::load_default().unwrap();
    let tok = Tokenizer::new();
    let nl = tok.encode("\n")[0];
    let prompt = tok.encode_with_bos("f3=8;g1=2;f3?\n");
    let out = xm
        .generate_greedy(&prompt, 24, &[gear_serve::model::config::EOS, nl])
        .unwrap();
    let text = tok.decode(&out);
    eprintln!("xla generated: {text:?}");
    assert!(out.len() <= 24);
}

#[test]
fn gear_attn_kernel_artifact_runs() {
    if !ready() {
        return;
    }
    // Execute the AOT-lowered Pallas fused-attention kernel and compare to
    // the golden oracle context vector.
    let art = Artifacts::load_default().unwrap();
    let Ok(path) = art.path("gear_attn_256") else {
        eprintln!("skipping: gear_attn artifact absent");
        return;
    };
    let g = {
        let bytes = std::fs::read(art.dir.join("golden/gear_attn.bin")).unwrap();
        gear_serve::model::weights::read_tensor_map(&bytes).unwrap()
    };
    let mut rt = gear_serve::runtime::XlaRuntime::cpu().unwrap();
    rt.load("gear_attn", &path).unwrap();

    use gear_serve::runtime::executable::{i32_literal, i32_scalar, slice_to_literal};
    let n_bucket = 256usize;
    let (n, d) = (g["codes"].rows(), g["codes"].cols());
    let h = g["a"].shape()[0];
    let r = g["a"].shape()[2];
    let dh = d / h;
    // Pad golden inputs (n=32) into the n=256 bucket.
    let mut codes = vec![0i32; n_bucket * d];
    let mut v = vec![0f32; n_bucket * d];
    for t in 0..n {
        for c in 0..d {
            codes[t * d + c] = g["codes"].data()[t * d + c] as i32;
            v[t * d + c] = g["v"].data()[t * d + c];
        }
    }
    let mut a = vec![0f32; h * n_bucket * r];
    for hh in 0..h {
        for t in 0..n {
            for ri in 0..r {
                a[hh * n_bucket * r + t * r + ri] = g["a"].data()[hh * n * r + t * r + ri];
            }
        }
    }
    let out = rt
        .run(
            "gear_attn",
            &[
                slice_to_literal(g["q"].data(), &[d]).unwrap(),
                i32_literal(&codes, &[n_bucket, d]).unwrap(),
                slice_to_literal(g["scales"].data(), &[d]).unwrap(),
                slice_to_literal(g["zeros"].data(), &[d]).unwrap(),
                slice_to_literal(&a, &[h, n_bucket, r]).unwrap(),
                slice_to_literal(g["b"].data(), &[h, dh, r]).unwrap(),
                slice_to_literal(&v, &[n_bucket, d]).unwrap(),
                i32_scalar(n as i32),
            ],
        )
        .unwrap();
    let ctx = out[0].to_vec::<f32>().unwrap();
    let want = g["ctx"].data();
    let mut worst = 0f32;
    for (x, y) in ctx.iter().zip(want) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst < 1e-3, "gear_attn HLO vs oracle: max diff {worst}");
}
