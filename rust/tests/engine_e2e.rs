//! End-to-end engine integration over the trained model (skips accuracy
//! assertions when artifacts are absent, exercising the machinery with
//! random weights instead).

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::GenRequest;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::{ModelConfig, Tokenizer};
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::workload::tasks::{self, Task};

fn model() -> (Model, bool) {
    if Artifacts::available() {
        let w = ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap();
        (Model::new(w), true)
    } else {
        eprintln!("artifacts absent: using random weights (no accuracy assertions)");
        let cfg = ModelConfig { vocab: 49, d_model: 64, n_layers: 2, n_heads: 4, max_seq: 320 };
        (Model::new(ModelWeights::random(cfg, 3)), false)
    }
}

fn accuracy(engine: &mut Engine, set: &[tasks::TaskInstance]) -> f64 {
    let tok = Tokenizer::new();
    for (i, inst) in set.iter().enumerate() {
        engine.submit(
            GenRequest::greedy(i as u64, tok.encode_with_bos(&inst.prompt), 48)
                .with_newline_stop(),
        );
    }
    let results = engine.run_to_completion();
    assert_eq!(results.len(), set.len());
    let mut correct = 0;
    for r in &results {
        if tasks::score(&r.text(), &set[r.id as usize]) {
            correct += 1;
        }
    }
    correct as f64 / set.len() as f64
}

#[test]
fn easy_task_end_to_end() {
    let (model, trained) = model();
    let set = tasks::generate_set(Task::KvRecall { pairs: 10 }, 20, 11);
    let mut engine = Engine::new(model, EngineConfig::new(CacheSpec::Fp16));
    let acc = accuracy(&mut engine, &set);
    eprintln!("kv-recall fp16 accuracy: {acc}");
    if trained {
        // The build-time budget trains the checkpoint to well above chance
        // (10 % for digit answers), not to convergence; the relative
        // method comparisons are what the benches measure.
        assert!(acc >= 0.15, "trained model should beat chance on kv-recall: {acc}");
    }
}

#[test]
fn hard_task_gear_close_to_fp16() {
    let (model, trained) = model();
    if !trained {
        return; // relative-accuracy claims need the trained checkpoint
    }
    let set = tasks::generate_set(Task::ChainArith { steps: 4, shots: 2 }, 20, 13);
    let weights = model.weights.clone();
    let run = |spec: CacheSpec| {
        let mut e = Engine::new(Model::new(weights.clone()), EngineConfig::new(spec));
        accuracy(&mut e, &set)
    };
    let fp16 = run(CacheSpec::Fp16);
    let gear = run(CacheSpec::gear(4));
    eprintln!("chain-arith fp16 {fp16} vs gear-4bit {gear}");
    // Near-lossless claim at 4-bit: within 15 points on this small sample.
    assert!(gear >= fp16 - 0.15, "gear-4 {gear} much worse than fp16 {fp16}");
}

#[test]
fn all_cache_specs_run_end_to_end() {
    let (model, _) = model();
    let weights = model.weights.clone();
    let tok = Tokenizer::new();
    let inst = tasks::generate_set(Task::easy(), 1, 5).remove(0);
    for spec in [
        CacheSpec::Fp16,
        CacheSpec::gear(2),
        CacheSpec::gear(4),
        CacheSpec::gear_l(2),
        CacheSpec::parse("kivi-2").unwrap(),
        CacheSpec::parse("kcvt-4").unwrap(),
        CacheSpec::parse("per-token-4").unwrap(),
        CacheSpec::parse("h2o-50").unwrap(),
    ] {
        let mut e = Engine::new(Model::new(weights.clone()), EngineConfig::new(spec));
        e.submit(
            GenRequest::greedy(0, tok.encode_with_bos(&inst.prompt), 16).with_newline_stop(),
        );
        let r = e.run_to_completion();
        assert_eq!(r.len(), 1, "{}", spec.label());
    }
}

#[test]
fn spec_parser_round_trips() {
    for s in ["fp16", "gear-2", "gear-4", "gear-l-2", "kivi-4", "kcvt-2", "per-token-4", "h2o-25"] {
        assert!(CacheSpec::parse(s).is_some(), "{s}");
    }
    assert!(CacheSpec::parse("gear-3").is_none());
    assert!(CacheSpec::parse("bogus").is_none());
}
