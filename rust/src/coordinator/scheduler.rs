//! The scheduling plane: admission, budget accounting, and preemption
//! *policy*. No model math happens here — the execution plane
//! ([`super::executor`]) owns that. The engine composes the two.
//!
//! Policy (vLLM-flavored):
//! * **Admission** — FCFS while the active set is below `max_batch` and the
//!   byte budget can hold a conservative whole-lifetime estimate of the
//!   request's cache. Admission is immediate: the prompt is *not* prefilled
//!   here — the request enters the active set in [`ReqPhase::Prefill`] and
//!   the engine's sweep loop runs its prefill in fixed-size chunks
//!   interleaved with decode, so a long prompt never stalls the batch.
//! * **Preemption** — when a reservation cannot grow mid-sweep, the
//!   *youngest* active request is preempted (recompute preemption: cache
//!   and any half-finished prefill state dropped, requeued at the front).
//!   A request that cannot fit even alone finishes as `OutOfMemory`.
//!
//! Everything is deterministic: FCFS order, per-request seeded samplers,
//! and fixed iteration order in the engine's reserve and commit phases.
//! Policy never observes anything timing-dependent — budget decisions read
//! cache bytes only at the engine's commit points, where any asynchronous
//! flush the request submitted has already been joined — so the schedule
//! (admissions, preemptions, OOMs) is bit-identical across
//! [`super::executor::ExecMode`]s and pool sizes. See
//! `docs/ARCHITECTURE.md` for the full concurrency contract.

use std::collections::VecDeque;
use std::time::Instant;

use crate::kvcache::budget::MemoryBudget;
use crate::kvcache::{CacheSpec, RequestCache};
use crate::model::{Model, PrefillState};
use crate::util::rng::Rng;

use super::engine::EngineConfig;
use super::executor::FlushTicket;
use super::metrics::EngineMetrics;
use super::request::{FinishReason, GenRequest, GenResult};

/// Where an active request is in its lifecycle.
pub enum ReqPhase {
    /// Prompt prefill in flight: chunks of the prompt are processed one
    /// engine sweep at a time. The cache stays empty until the final chunk
    /// commits, so preempting a half-prefilled request rolls back cleanly —
    /// there is nothing to unwind beyond dropping the state.
    Prefill(PrefillState),
    /// Prefill committed; the request decodes one token per sweep.
    Decode,
}

/// One admitted request's full state. Owned by the engine's active set; the
/// executor borrows `(next_token, pos, cache)` (decode) or the prefill
/// state (prefill) for each sweep.
pub struct ActiveRequest {
    /// Engine-internal admission serial, unique per (re)admission. The
    /// commit phase keys on this rather than `req.id` — caller-chosen ids
    /// are not required to be unique.
    pub serial: u64,
    pub req: GenRequest,
    pub cache: RequestCache,
    pub phase: ReqPhase,
    /// Steady bytes reserved in the budget for this request: the admission
    /// estimate, grown to the largest real cache size seen.
    pub reserved: usize,
    /// Transient bytes reserved *above* `reserved` for the current sweep
    /// (step-growth headroom, or in-flight prefill KV). Folded back into
    /// `reserved`/released when the sweep's work for this request commits.
    pub headroom: usize,
    pub output: Vec<u32>,
    /// Next token to feed (last sampled). Meaningless until prefill
    /// commits.
    pub next_token: u32,
    /// Position of the next decode step. Meaningless until prefill commits.
    pub pos: usize,
    pub preemptions: usize,
    pub rng: Rng,
    pub enqueued_at: Instant,
    pub started_at: Instant,
    /// Flush jobs detached at this request's last commit and still
    /// compressing asynchronously: `(layer index, ticket)`, in layer order.
    /// Joined — in this fixed order — at the request's next commit, the
    /// first point byte accounting must observe the results. Dropped (jobs
    /// abandoned) when the request is preempted or finishes first: a
    /// preempted request restarts from an empty cache, so the segments can
    /// no longer matter.
    pub pending_flushes: Vec<(usize, FlushTicket)>,
}

impl ActiveRequest {
    /// Consume into a finished result.
    pub fn into_result(self, finish: FinishReason) -> GenResult {
        GenResult {
            id: self.req.id,
            output: self.output,
            finish,
            prompt_len: self.req.prompt.len(),
            preemptions: self.preemptions,
            queue_secs: (self.started_at - self.enqueued_at).as_secs_f64(),
            run_secs: self.started_at.elapsed().as_secs_f64(),
        }
    }
}

/// Admission queue + memory budget: the policy half of the engine.
pub struct Scheduler {
    cfg: EngineConfig,
    pub budget: MemoryBudget,
    waiting: VecDeque<(GenRequest, Instant, usize)>,
    /// Next admission serial (see [`ActiveRequest::serial`]).
    next_serial: u64,
}

impl Scheduler {
    pub fn new(cfg: EngineConfig) -> Scheduler {
        let budget = MemoryBudget::new(cfg.budget_bytes);
        Scheduler { cfg, budget, waiting: VecDeque::new(), next_serial: 0 }
    }

    pub fn cfg(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.waiting.push_back((req, Instant::now(), 0));
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Requeue a preempted request at the front with its original enqueue
    /// time (recompute preemption).
    pub fn requeue_front(&mut self, req: GenRequest, enqueued_at: Instant, preemptions: usize) {
        self.waiting.push_front((req, enqueued_at, preemptions));
    }

    /// Conservative cache-size estimate for admission: prompt + full
    /// generation at the configured compression ratio, via the analytic
    /// size model (FP16 methods estimate at 100%).
    fn estimate_bytes(&self, model: &Model, prompt_len: usize, max_new: usize) -> usize {
        let c = model.config();
        let n = prompt_len + max_new;
        let frac = match self.cfg.spec {
            CacheSpec::Fp16 => 1.0,
            CacheSpec::Compressed { method, buffer, .. } => {
                // 1.25 safety factor: decode-phase chunks (n_b tokens at
                // rank r_g) carry proportionally more low-rank/meta overhead
                // than the analytic whole-matrix prediction.
                1.25 * crate::gear::size::predict_cache_frac(
                    method,
                    n,
                    c.d_model,
                    c.n_layers,
                    c.n_heads,
                    buffer,
                )
            }
            CacheSpec::H2o { keep, .. } => keep.max(0.05) + 0.05,
        };
        (c.fp16_kv_bytes(n) as f64 * frac).ceil() as usize
    }

    /// Admit waiting requests FCFS into `active` while the batch and byte
    /// budgets allow. Admission reserves the conservative estimate and
    /// creates the request in [`ReqPhase::Prefill`]; the engine's sweeps
    /// run the prefill in chunks. Requests that can never fit finish as
    /// `OutOfMemory`.
    pub fn try_admit(
        &mut self,
        model: &Model,
        active: &mut Vec<ActiveRequest>,
        finished: &mut Vec<GenResult>,
        metrics: &mut EngineMetrics,
    ) {
        while active.len() < self.cfg.max_batch {
            let Some((req, enq, preemptions)) = self.waiting.front().cloned() else { break };
            let est = self.estimate_bytes(model, req.prompt.len(), req.max_new_tokens);
            if !self.budget.try_reserve(est) {
                // Can it ever fit? If nothing is active and it still fails,
                // reject rather than deadlock.
                if active.is_empty() {
                    self.waiting.pop_front();
                    metrics.requests_oom += 1;
                    finished.push(GenResult {
                        id: req.id,
                        output: Vec::new(),
                        finish: FinishReason::OutOfMemory,
                        prompt_len: req.prompt.len(),
                        preemptions,
                        queue_secs: enq.elapsed().as_secs_f64(),
                        run_secs: 0.0,
                    });
                    continue;
                }
                break;
            }
            self.waiting.pop_front();

            assert!(!req.prompt.is_empty(), "empty prompt");
            let c = model.config();
            let cache = RequestCache::new(&self.cfg.spec, c.n_layers, c.d_model, c.n_heads);
            let state = PrefillState::new(c, req.prompt.len());
            let rng = Rng::new(self.cfg.seed ^ req.id.wrapping_mul(0x9E3779B97F4A7C15));
            let serial = self.next_serial;
            self.next_serial += 1;
            active.push(ActiveRequest {
                serial,
                req,
                cache,
                phase: ReqPhase::Prefill(state),
                reserved: est,
                headroom: 0,
                output: Vec::new(),
                next_token: 0,
                pos: 0,
                preemptions,
                rng,
                enqueued_at: enq,
                started_at: Instant::now(),
                pending_flushes: Vec::new(),
            });
            metrics.max_concurrency = metrics.max_concurrency.max(active.len());
        }
    }

    /// Preempt the youngest active request (highest `started_at`): release
    /// everything it holds (steady reservation + sweep headroom) and
    /// requeue it at the front. A half-prefilled victim needs no unwinding:
    /// its cache is still empty (prefill commits atomically) and the
    /// in-flight state drops with it — recompute preemption restarts the
    /// prefill from scratch on re-admission. If it was the *only* active
    /// request it can never fit and finishes as `OutOfMemory` (avoids a
    /// preempt/re-admit livelock).
    pub fn preempt_youngest(
        &mut self,
        active: &mut Vec<ActiveRequest>,
        finished: &mut Vec<GenResult>,
        metrics: &mut EngineMetrics,
    ) {
        if let Some(idx) = (0..active.len()).max_by_key(|&i| active[i].started_at) {
            let a = active.swap_remove(idx);
            self.budget.release(a.reserved + a.headroom);
            if active.is_empty() {
                metrics.requests_oom += 1;
                finished.push(a.into_result(FinishReason::OutOfMemory));
                return;
            }
            metrics.requests_preempted += 1;
            let (req, enq, preemptions) = (a.req, a.enqueued_at, a.preemptions + 1);
            self.requeue_front(req, enq, preemptions);
        }
    }
}
