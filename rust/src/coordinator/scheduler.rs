//! The scheduling plane: admission, budget accounting, and preemption
//! *policy*. No model math happens here — the execution plane
//! ([`super::executor`]) owns that. The engine composes the two.
//!
//! Policy (vLLM-flavored):
//! * **Admission** — FCFS while the active set is below `max_batch` and the
//!   byte budget can hold a conservative whole-lifetime estimate of the
//!   request's cache. Admission is immediate: the prompt is *not* prefilled
//!   here — the request enters the active set in [`ReqPhase::Prefill`] and
//!   the engine's sweep loop runs its prefill in fixed-size chunks
//!   interleaved with decode, so a long prompt never stalls the batch.
//! * **Preemption** — when a reservation cannot grow mid-sweep, the
//!   *youngest* active request is preempted (recompute preemption: cache
//!   and any half-finished prefill state dropped, requeued at the front).
//!   A request that cannot fit even alone finishes as `OutOfMemory`.
//!
//! Everything is deterministic: FCFS order, per-request seeded samplers,
//! and fixed iteration order in the engine's reserve and commit phases.
//! Policy never observes anything timing-dependent — budget decisions read
//! cache bytes only at the engine's commit points, where any asynchronous
//! flush the request submitted has already been joined — so the schedule
//! (admissions, preemptions, OOMs) is bit-identical across
//! [`super::executor::ExecMode`]s and pool sizes. See
//! `docs/ARCHITECTURE.md` for the full concurrency contract.

use std::collections::VecDeque;
use std::time::Instant;

use crate::kvcache::budget::MemoryBudget;
use crate::kvcache::{CacheSpec, RequestCache};
use crate::model::{Model, PrefillState};
use crate::trace::{EventKind, FinishClass, Tracer};
use crate::util::rng::Rng;

use super::engine::EngineConfig;
use super::executor::{default_hybrid_threshold, FlushTicket, Plane};
use super::metrics::EngineMetrics;
use super::request::{FinishReason, GenRequest, GenResult};

/// Per-sweep plane selection for [`super::executor::ExecMode::Hybrid`]:
/// pipeline when the decode batch is small (below the threshold the batch
/// plane needs to fan out), batch-chunked at or above it, with hysteresis
/// so a batch oscillating around the threshold doesn't thrash.
///
/// The rules, with `t = threshold` and `m = margin` (fixed at 1):
/// * No plane chosen yet: `batch >= t` picks [`Plane::Batched`], else
///   [`Plane::Pipelined`].
/// * Currently pipelined: switch to batched only when `batch >= t`.
/// * Currently batched: switch to pipelined only when `batch + m < t` —
///   i.e. the batch must drop *strictly below* `t - m`, not merely below
///   `t`. A batch bouncing between `t - 1` and `t` therefore switches at
///   most once per crossing direction instead of every sweep.
///
/// The policy reads only the decode batch size — a value that is itself
/// bit-identical across planes (the determinism contract) — so the chosen
/// plane sequence is deterministic, and since both planes are bit-identical
/// to `Sequential`, the choice can never affect results; it only moves
/// work between equivalent schedules. Selection is part of the engine's
/// fixed-order policy phase (`tests/hybrid_golden.rs` pins all of this).
#[derive(Debug, Clone)]
pub struct PlanePolicy {
    threshold: usize,
    margin: usize,
    current: Option<Plane>,
    switches: usize,
}

impl PlanePolicy {
    /// Policy with the given switch threshold (clamped to at least 1; a
    /// threshold of 1 means every non-empty batch runs batch-chunked).
    pub fn new(threshold: usize) -> PlanePolicy {
        PlanePolicy { threshold: threshold.max(1), margin: 1, current: None, switches: 0 }
    }

    /// Choose the plane for a sweep decoding `decode_batch` requests,
    /// applying the hysteresis rules above and recording a switch when the
    /// choice differs from the previous sweep's.
    pub fn choose(&mut self, decode_batch: usize) -> Plane {
        let next = match self.current {
            None => {
                if decode_batch >= self.threshold {
                    Plane::Batched
                } else {
                    Plane::Pipelined
                }
            }
            Some(Plane::Pipelined) => {
                if decode_batch >= self.threshold {
                    Plane::Batched
                } else {
                    Plane::Pipelined
                }
            }
            Some(Plane::Batched) => {
                if decode_batch + self.margin < self.threshold {
                    Plane::Pipelined
                } else {
                    Plane::Batched
                }
            }
        };
        if self.current.is_some() && self.current != Some(next) {
            self.switches += 1;
        }
        self.current = Some(next);
        next
    }

    /// The configured switch threshold.
    pub fn threshold(&self) -> usize {
        self.threshold
    }

    /// The plane the most recent [`Self::choose`] picked, if any.
    pub fn current(&self) -> Option<Plane> {
        self.current
    }

    /// Number of plane switches recorded so far (the first choice is not a
    /// switch).
    pub fn switches(&self) -> usize {
        self.switches
    }
}

/// Where an active request is in its lifecycle.
pub enum ReqPhase {
    /// Prompt prefill in flight: chunks of the prompt are processed one
    /// engine sweep at a time. The cache stays empty until the final chunk
    /// commits, so preempting a half-prefilled request rolls back cleanly —
    /// there is nothing to unwind beyond dropping the state.
    Prefill(PrefillState),
    /// Prefill committed; the request decodes one token per sweep.
    Decode,
}

/// One admitted request's full state. Owned by the engine's active set; the
/// executor borrows `(next_token, pos, cache)` (decode) or the prefill
/// state (prefill) for each sweep.
pub struct ActiveRequest {
    /// Engine-internal admission serial, unique per (re)admission. The
    /// commit phase keys on this rather than `req.id` — caller-chosen ids
    /// are not required to be unique.
    pub serial: u64,
    pub req: GenRequest,
    pub cache: RequestCache,
    pub phase: ReqPhase,
    /// Steady bytes reserved in the budget for this request: the admission
    /// estimate, grown to the largest real cache size seen.
    pub reserved: usize,
    /// Transient bytes reserved *above* `reserved` for the current sweep
    /// (step-growth headroom, or in-flight prefill KV). Folded back into
    /// `reserved`/released when the sweep's work for this request commits.
    pub headroom: usize,
    pub output: Vec<u32>,
    /// Next token to feed (last sampled). Meaningless until prefill
    /// commits.
    pub next_token: u32,
    /// Position of the next decode step. Meaningless until prefill commits.
    pub pos: usize,
    pub preemptions: usize,
    pub rng: Rng,
    pub enqueued_at: Instant,
    pub started_at: Instant,
    /// Flush jobs detached at this request's last commit and still
    /// compressing asynchronously: `(layer index, ticket)`, in layer order.
    /// Joined — in this fixed order — at the request's next commit, the
    /// first point byte accounting must observe the results. Dropped (jobs
    /// abandoned) when the request is preempted or finishes first: a
    /// preempted request restarts from an empty cache, so the segments can
    /// no longer matter.
    pub pending_flushes: Vec<(usize, FlushTicket)>,
}

impl ActiveRequest {
    /// Consume into a finished result.
    pub fn into_result(self, finish: FinishReason) -> GenResult {
        GenResult {
            id: self.req.id,
            output: self.output,
            finish,
            prompt_len: self.req.prompt.len(),
            preemptions: self.preemptions,
            queue_secs: (self.started_at - self.enqueued_at).as_secs_f64(),
            run_secs: self.started_at.elapsed().as_secs_f64(),
        }
    }
}

/// Admission queue + memory budget: the policy half of the engine.
pub struct Scheduler {
    cfg: EngineConfig,
    pub budget: MemoryBudget,
    waiting: VecDeque<(GenRequest, Instant, usize)>,
    /// Next admission serial (see [`ActiveRequest::serial`]).
    next_serial: u64,
    /// Per-sweep plane selection for `ExecMode::Hybrid` (unused by the
    /// fixed modes). Scheduler-side because it is pure policy: it reads
    /// the deterministic decode-batch sequence and nothing else.
    pub plane_policy: PlanePolicy,
}

impl Scheduler {
    pub fn new(cfg: EngineConfig) -> Scheduler {
        let budget = MemoryBudget::new(cfg.budget_bytes);
        let plane_policy =
            PlanePolicy::new(cfg.hybrid_threshold.unwrap_or_else(default_hybrid_threshold));
        Scheduler { cfg, budget, waiting: VecDeque::new(), next_serial: 0, plane_policy }
    }

    pub fn cfg(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.waiting.push_back((req, Instant::now(), 0));
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Requeue a preempted request at the front with its original enqueue
    /// time (recompute preemption).
    pub fn requeue_front(&mut self, req: GenRequest, enqueued_at: Instant, preemptions: usize) {
        self.waiting.push_front((req, enqueued_at, preemptions));
    }

    /// Conservative cache-size estimate for admission: prompt + full
    /// generation at the configured compression ratio, via the analytic
    /// size model (FP16 methods estimate at 100%).
    fn estimate_bytes(&self, model: &Model, prompt_len: usize, max_new: usize) -> usize {
        let c = model.config();
        let n = prompt_len + max_new;
        let frac = match self.cfg.spec {
            CacheSpec::Fp16 => 1.0,
            CacheSpec::Compressed { method, buffer, .. } => {
                // 1.25 safety factor: decode-phase chunks (n_b tokens at
                // rank r_g) carry proportionally more low-rank/meta overhead
                // than the analytic whole-matrix prediction.
                1.25 * crate::gear::size::predict_cache_frac(
                    method,
                    n,
                    c.d_model,
                    c.n_layers,
                    c.n_heads,
                    buffer,
                )
            }
            CacheSpec::H2o { keep, .. } => keep.max(0.05) + 0.05,
        };
        (c.fp16_kv_bytes(n) as f64 * frac).ceil() as usize
    }

    /// Admit waiting requests FCFS into `active` while the batch and byte
    /// budgets allow. Admission reserves the conservative estimate and
    /// creates the request in [`ReqPhase::Prefill`]; the engine's sweeps
    /// run the prefill in chunks. Requests that can never fit finish as
    /// `OutOfMemory`.
    ///
    /// On traced runs each admission emits [`EventKind::Admit`]; an
    /// admission-time OOM rejection consumes a serial too and emits a
    /// bare [`EventKind::Finish`] (there is no matching `Admit` — the
    /// request never entered the active set).
    pub fn try_admit(
        &mut self,
        model: &Model,
        active: &mut Vec<ActiveRequest>,
        finished: &mut Vec<GenResult>,
        metrics: &mut EngineMetrics,
        tracer: &mut Option<Tracer>,
    ) {
        while active.len() < self.cfg.max_batch {
            // Estimate from a borrow of the queue head — the request (and
            // its whole prompt vector) is popped only once admission or
            // OOM-rejection is certain, so a failed attempt costs two
            // scalar reads, not a `GenRequest` clone.
            let Some((head, _, _)) = self.waiting.front() else { break };
            let (prompt_len, max_new) = (head.prompt.len(), head.max_new_tokens);
            let est = self.estimate_bytes(model, prompt_len, max_new);
            if !self.budget.try_reserve(est) {
                // Can it ever fit? If nothing is active and it still fails,
                // reject rather than deadlock.
                if active.is_empty() {
                    let (req, enq, preemptions) =
                        self.waiting.pop_front().expect("peeked head vanished");
                    metrics.requests_oom += 1;
                    // Rejections consume a serial so every Finish event
                    // carries a unique one (serials are engine-internal
                    // and nothing else observes the gap).
                    let serial = self.next_serial;
                    self.next_serial += 1;
                    if let Some(t) = tracer {
                        t.emit(EventKind::Finish {
                            serial,
                            reason: FinishClass::Oom,
                            tokens: 0,
                        });
                    }
                    finished.push(GenResult {
                        id: req.id,
                        output: Vec::new(),
                        finish: FinishReason::OutOfMemory,
                        prompt_len: req.prompt.len(),
                        preemptions,
                        queue_secs: enq.elapsed().as_secs_f64(),
                        run_secs: 0.0,
                    });
                    continue;
                }
                break;
            }
            let (req, enq, preemptions) =
                self.waiting.pop_front().expect("peeked head vanished");

            assert!(!req.prompt.is_empty(), "empty prompt");
            let c = model.config();
            let cache = RequestCache::new(&self.cfg.spec, c.n_layers, c.d_model, c.n_heads);
            let state = PrefillState::new(c, req.prompt.len());
            let rng = Rng::new(self.cfg.seed ^ req.id.wrapping_mul(0x9E3779B97F4A7C15));
            let serial = self.next_serial;
            self.next_serial += 1;
            if let Some(t) = tracer {
                t.emit(EventKind::Admit { serial, req_id: req.id });
            }
            active.push(ActiveRequest {
                serial,
                req,
                cache,
                phase: ReqPhase::Prefill(state),
                reserved: est,
                headroom: 0,
                output: Vec::new(),
                next_token: 0,
                pos: 0,
                preemptions,
                rng,
                enqueued_at: enq,
                started_at: Instant::now(),
                pending_flushes: Vec::new(),
            });
            metrics.max_concurrency = metrics.max_concurrency.max(active.len());
        }
    }

    /// Preempt the youngest active request — the one with the highest
    /// admission `serial`, which is clock-independent: requests admitted in
    /// the same `try_admit` pass can tie on a coarse monotonic `started_at`
    /// clock, and a timing-dependent victim would break the bit-identical
    /// schedule contract. Release everything the victim holds (steady
    /// reservation + sweep headroom) and requeue it at the front. A
    /// half-prefilled victim needs no unwinding: its cache is still empty
    /// (prefill commits atomically) and the in-flight state drops with it —
    /// recompute preemption restarts the prefill from scratch on
    /// re-admission. If it was the *only* active request it can never fit
    /// and finishes as `OutOfMemory` (avoids a preempt/re-admit livelock).
    pub fn preempt_youngest(
        &mut self,
        active: &mut Vec<ActiveRequest>,
        finished: &mut Vec<GenResult>,
        metrics: &mut EngineMetrics,
        tracer: &mut Option<Tracer>,
    ) {
        if let Some(idx) = (0..active.len()).max_by_key(|&i| active[i].serial) {
            let a = active.swap_remove(idx);
            self.budget.release(a.reserved + a.headroom);
            if active.is_empty() {
                metrics.requests_oom += 1;
                if let Some(t) = tracer {
                    t.emit(EventKind::Preempt { serial: a.serial, oom: true });
                    t.emit(EventKind::Finish {
                        serial: a.serial,
                        reason: FinishClass::Oom,
                        tokens: a.output.len() as u32,
                    });
                }
                finished.push(a.into_result(FinishReason::OutOfMemory));
                return;
            }
            metrics.requests_preempted += 1;
            if let Some(t) = tracer {
                t.emit(EventKind::Preempt { serial: a.serial, oom: false });
            }
            let (req, enq, preemptions) = (a.req, a.enqueued_at, a.preemptions + 1);
            self.requeue_front(req, enq, preemptions);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::{Model, ModelWeights};

    fn tiny_model() -> Model {
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 64 };
        Model::new(ModelWeights::random(cfg, 3))
    }

    /// Requests admitted in one `try_admit` pass can receive identical
    /// `started_at` values from a coarse monotonic clock; the preemption
    /// victim must therefore be chosen by admission serial, never by
    /// wall-clock age.
    #[test]
    fn preempt_victim_keyed_on_serial_not_clock() {
        let model = tiny_model();
        let cfg = EngineConfig::new(CacheSpec::Fp16).with_max_batch(8);
        let mut sched = Scheduler::new(cfg);
        let (mut active, mut finished) = (Vec::new(), Vec::new());
        let mut metrics = EngineMetrics::default();
        for i in 0..4 {
            sched.submit(GenRequest::greedy(i, vec![1, 2, 3], 4));
        }
        sched.try_admit(&model, &mut active, &mut finished, &mut metrics, &mut None);
        assert_eq!(active.len(), 4);
        // Force the tie the clock can produce on its own: every candidate
        // started at the same instant.
        let t = active[0].started_at;
        for a in active.iter_mut() {
            a.started_at = t;
        }
        sched.preempt_youngest(&mut active, &mut finished, &mut metrics, &mut None);
        assert_eq!(active.len(), 3);
        assert!(
            active.iter().all(|a| a.serial != 3),
            "victim must be the youngest admission (serial 3)"
        );
        assert_eq!(sched.waiting_len(), 1, "victim requeued at the front");
        sched.preempt_youngest(&mut active, &mut finished, &mut metrics, &mut None);
        assert!(active.iter().all(|a| a.serial <= 1), "then serial 2");
        assert_eq!(metrics.requests_preempted, 2);
        assert!(finished.is_empty(), "preemption with survivors never OOM-finishes");
    }

    /// A failed admission attempt must leave the queue head untouched (no
    /// pop, no reorder) so the request is retried verbatim once budget
    /// frees up.
    #[test]
    fn failed_admission_keeps_queue_intact() {
        let model = tiny_model();
        // Tiny budget, but something active: admission fails without OOM.
        let cfg = EngineConfig::new(CacheSpec::Fp16).with_budget(1).with_max_batch(8);
        let mut sched = Scheduler::new(cfg);
        let (mut active, mut finished) = (Vec::new(), Vec::new());
        let mut metrics = EngineMetrics::default();
        sched.submit(GenRequest::greedy(7, vec![1, 2, 3, 4], 4));
        // Fake an occupant so the no-active OOM path is not taken.
        active.push(ActiveRequest {
            serial: 0,
            req: GenRequest::greedy(0, vec![1], 1),
            cache: RequestCache::new(&CacheSpec::Fp16, 2, 32, 4),
            phase: ReqPhase::Decode,
            reserved: 0,
            headroom: 0,
            output: Vec::new(),
            next_token: 0,
            pos: 0,
            preemptions: 0,
            rng: Rng::new(0),
            enqueued_at: Instant::now(),
            started_at: Instant::now(),
            pending_flushes: Vec::new(),
        });
        sched.try_admit(&model, &mut active, &mut finished, &mut metrics, &mut None);
        assert_eq!(active.len(), 1, "nothing admitted under an exhausted budget");
        assert_eq!(sched.waiting_len(), 1, "the head request still waits, unchanged");
        assert_eq!(metrics.requests_oom, 0);
        assert!(finished.is_empty());
    }

    /// Hysteresis: a batch oscillating between `t` and `t - 1` must switch
    /// at most once per crossing direction, not once per sweep. Only a
    /// drop strictly below `t - margin` sends a batched policy back to the
    /// pipeline plane.
    #[test]
    fn plane_policy_hysteresis() {
        let mut p = PlanePolicy::new(4);
        assert_eq!(p.threshold(), 4);
        assert_eq!(p.current(), None);
        // First choice: plain threshold comparison, not a switch.
        assert_eq!(p.choose(1), Plane::Pipelined);
        assert_eq!(p.switches(), 0);
        // Rising through the threshold switches once...
        assert_eq!(p.choose(4), Plane::Batched);
        assert_eq!(p.switches(), 1);
        // ...and the t / t-1 oscillation then sticks to Batched: 3 + 1 is
        // not strictly below 4.
        for b in [3, 4, 3, 4, 3] {
            assert_eq!(p.choose(b), Plane::Batched, "batch {b} must not thrash");
        }
        assert_eq!(p.switches(), 1, "no extra switches while oscillating");
        // A real drop (below t - margin) switches back exactly once.
        assert_eq!(p.choose(2), Plane::Pipelined);
        assert_eq!(p.switches(), 2);
        // And from Pipelined, anything short of t stays pipelined.
        assert_eq!(p.choose(3), Plane::Pipelined);
        assert_eq!(p.switches(), 2);

        // Threshold 1: every non-empty batch is batch-chunked from the
        // first choice on (1 + margin < 1 is never true).
        let mut p1 = PlanePolicy::new(1);
        assert_eq!(p1.choose(1), Plane::Batched);
        assert_eq!(p1.choose(0), Plane::Batched, "0 + 1 < 1 is false: sticky");
        assert_eq!(p1.switches(), 0);
        // Degenerate threshold clamps to 1.
        assert_eq!(PlanePolicy::new(0).threshold(), 1);
    }
}
