//! Minimal TCP front-end: newline-delimited text protocol.
//!
//! Client sends one prompt per line; the server replies with one generated
//! line per prompt (in request order per connection). One engine thread owns
//! the model; connection threads communicate with it over channels. Used by
//! `gear-serve serve` and the `serve_requests` example.
//!
//! One verb is reserved: a line consisting of exactly `metrics` is not a
//! prompt — it returns the engine's plain-text metrics snapshot
//! ([`crate::coordinator::EngineMetrics::render_text`], including the
//! `trace_*` lines when tracing is on), terminated by a blank line. The
//! snapshot refreshes after each engine batch completes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::util::error::{Error, Result};

use crate::model::config::Tokenizer;
use crate::model::Model;

use super::engine::{Engine, EngineConfig};
use super::request::{GenRequest, GenResult};

struct Submission {
    req: GenRequest,
    reply: Sender<GenResult>,
}

/// Handle for submitting work to a running engine thread.
#[derive(Clone)]
pub struct EngineClient {
    tx: Sender<Submission>,
    next_id: Arc<AtomicU64>,
    metrics_text: Arc<Mutex<String>>,
}

impl EngineClient {
    /// Submit a prompt; blocks until generation finishes.
    pub fn generate(&self, prompt: Vec<u32>, max_new_tokens: usize) -> Result<GenResult> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let req = GenRequest::greedy(id, prompt, max_new_tokens).with_newline_stop();
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Submission { req, reply: reply_tx })
            .map_err(|_| Error::msg("engine thread terminated"))?;
        reply_rx.recv().map_err(|_| Error::msg("engine dropped request"))
    }

    /// Latest plain-text metrics snapshot (empty before the first batch
    /// completes). Refreshed by the engine thread after each
    /// `run_to_completion`, so it reflects cumulative totals.
    pub fn metrics_text(&self) -> String {
        self.metrics_text.lock().unwrap().clone()
    }
}

/// Spawn the engine thread; returns a client handle.
///
/// The engine loop batches whatever submissions arrived since the last
/// drain, runs them to completion, and replies — a simple blocking form of
/// continuous batching appropriate for a single-core testbed.
pub fn spawn_engine(model: Model, cfg: EngineConfig) -> EngineClient {
    let (tx, rx): (Sender<Submission>, Receiver<Submission>) = channel();
    let metrics_text = Arc::new(Mutex::new(String::new()));
    let snapshot = Arc::clone(&metrics_text);
    std::thread::spawn(move || {
        let mut engine = Engine::new(model, cfg);
        let mut pending: Vec<(u64, Sender<GenResult>)> = Vec::new();
        loop {
            // Block for the first submission, then drain the burst.
            let first = match rx.recv() {
                Ok(s) => s,
                Err(_) => return,
            };
            pending.push((first.req.id, first.reply));
            engine.submit(first.req);
            while let Ok(s) = rx.try_recv() {
                pending.push((s.req.id, s.reply));
                engine.submit(s.req);
            }
            let results = engine.run_to_completion();
            // Publish the refreshed (cumulative) snapshot before any reply
            // lands, so a client that sees its result and immediately asks
            // for `metrics` reads a batch total that includes it.
            *snapshot.lock().unwrap() = engine.metrics.render_text();
            for result in results {
                if let Some(pos) = pending.iter().position(|(id, _)| *id == result.id) {
                    let (_, reply) = pending.swap_remove(pos);
                    let _ = reply.send(result);
                }
            }
        }
    });
    EngineClient { tx, next_id: Arc::new(AtomicU64::new(1)), metrics_text }
}

/// Serve the line protocol on `addr` until the process exits.
pub fn serve(addr: &str, client: EngineClient, max_new_tokens: usize) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("gear-serve listening on {addr}");
    let client = Arc::new(client);
    for stream in listener.incoming() {
        let stream = stream?;
        let client = Arc::clone(&client);
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &client, max_new_tokens) {
                eprintln!("connection error: {e}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, client: &EngineClient, max_new_tokens: usize) -> Result<()> {
    let peer = stream.peer_addr()?;
    let reader = BufReader::new(stream.try_clone()?);
    let writer = Arc::new(Mutex::new(stream));
    let tok = Tokenizer::new();
    for line in reader.lines() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        if line == "metrics" {
            // Reserved verb: dump the metrics snapshot, end with a blank
            // line so clients can read a variable-length reply.
            let mut w = writer.lock().unwrap();
            w.write_all(client.metrics_text().as_bytes())?;
            writeln!(w)?;
            continue;
        }
        // The task prompts end with '\n' which lines() strips; restore it.
        let prompt = tok.encode_with_bos(&format!("{line}\n"));
        let result = client.generate(prompt, max_new_tokens)?;
        let mut w = writer.lock().unwrap();
        writeln!(w, "{}", result.text().trim_end_matches('\n'))?;
    }
    eprintln!("connection {peer} closed");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheSpec;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    fn tiny_model() -> Model {
        let cfg = ModelConfig { vocab: 49, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 128 };
        Model::new(ModelWeights::random(cfg, 7))
    }

    #[test]
    fn engine_thread_round_trip() {
        let client = spawn_engine(tiny_model(), EngineConfig::new(CacheSpec::gear(4)));
        let tok = Tokenizer::new();
        let r = client.generate(tok.encode_with_bos("a=1;a?\n"), 8).unwrap();
        assert!(r.output.len() <= 8);
    }

    #[test]
    fn concurrent_clients() {
        let client = spawn_engine(tiny_model(), EngineConfig::new(CacheSpec::Fp16));
        let tok = Tokenizer::new();
        let mut handles = Vec::new();
        for i in 0..4 {
            let c = client.clone();
            let prompt = tok.encode_with_bos(&format!("k{i}=3;k{i}?\n"));
            handles.push(std::thread::spawn(move || c.generate(prompt, 6).unwrap()));
        }
        for h in handles {
            let r = h.join().unwrap();
            assert!(r.output.len() <= 6);
        }
    }

    #[test]
    fn tcp_end_to_end() {
        let client = spawn_engine(tiny_model(), EngineConfig::new(CacheSpec::gear(4)));
        // Port 0: let the OS pick.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let stream = stream.unwrap();
                let c = client.clone();
                std::thread::spawn(move || handle_conn(stream, &c, 6));
            }
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "a=3;a?").unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        // Untrained model: any decodable reply is fine; protocol must work.
        assert!(line.ends_with('\n'));
    }

    /// The `metrics` verb must return the engine's plain-text snapshot
    /// (terminated by a blank line), not treat the word as a prompt.
    #[test]
    fn metrics_verb_returns_snapshot() {
        let client = spawn_engine(tiny_model(), EngineConfig::new(CacheSpec::gear(4)));
        assert!(client.metrics_text().is_empty(), "no snapshot before the first batch");
        let tok = Tokenizer::new();
        client.generate(tok.encode_with_bos("m=2;m?\n"), 4).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server_client = client.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let stream = stream.unwrap();
                let c = server_client.clone();
                std::thread::spawn(move || handle_conn(stream, &c, 4));
            }
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "metrics").unwrap();
        let mut reader = BufReader::new(conn);
        let mut lines = Vec::new();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let line = line.trim_end().to_string();
            if line.is_empty() {
                break;
            }
            lines.push(line);
        }
        assert!(
            lines.iter().any(|l| l.starts_with("requests_finished ")),
            "snapshot must carry counters, got {lines:?}"
        );
        assert!(
            lines.iter().any(|l| l == "requests_finished 1"),
            "one request finished before the verb, got {lines:?}"
        );
    }
}
