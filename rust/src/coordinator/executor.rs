//! The execution plane: one batched decode step — and one batched round of
//! prefill chunks — over the whole active set, plus the asynchronous
//! flush-compression jobs the decode step seals.
//!
//! The executor owns no policy. It receives the active requests in engine
//! order, runs [`Model::decode_batch_into`] (decode) or
//! [`Model::prefill_chunk_batch`] (prefill) over them — layer-major, so
//! each block's weights are streamed once per sweep for the whole batch —
//! and writes per-request results in the same order.
//!
//! ## Persistent worker pool
//!
//! Parallelism comes from a long-lived `WorkerPool` owned by the
//! executor: `GEAR_POOL_THREADS` (default: host parallelism) threads are
//! spawned once per `BatchExecutor` and park on a condvar between sweeps.
//! Each worker pins one [`DecodeBufs`] — norm/qkv/ctx/mlp scratch, the
//! attention scratch with its per-segment kernel buffers, and the pooled
//! per-slot hidden-state vectors — for its whole lifetime, so a sweep does
//! no scratch setup and no O(batch) allocation: the old per-sweep
//! `std::thread::scope` spawn (thread create + fresh `DecodeBufs` + fresh
//! hidden/logits vectors per worker per sweep) is gone.
//!
//! Dispatch is deterministic: the batch is split into contiguous chunk
//! descriptors in engine order, workers claim chunks by index, and results
//! land directly in the caller's per-request slots — a fixed-order
//! reduction by construction. Every request's forward touches only its own
//! cache and hidden state, so which worker runs which chunk cannot change
//! results: decode and prefill are **bit-identical** to the sequential
//! reference for every pool size (`tests/pool_golden.rs` pins this).
//!
//! ## Layer-sharded pipeline plane ([`ExecMode::Pipelined`])
//!
//! The batch plane splits *requests* across workers, so below
//! [`MIN_FANOUT`] requests it degenerates to the inline path and a single
//! stream gets zero speedup. The pipeline plane splits *layers* instead:
//! the model's blocks are partitioned into contiguous **stages** (one per
//! pool worker by default; `GEAR_PIPELINE_STAGES` /
//! [`super::engine::EngineConfig::with_pipeline_stages`] override, clamped
//! to the layer count), and each request's hidden state streams
//! stage-to-stage through a bounded one-slot hand-off. Stage `s` runs
//! request `i`'s layers while stage `s+1` runs request `i-1`'s — so decode
//! parallelizes even at batch = 1, where the batch plane cannot.
//!
//! The hand-off is a per-stage progress counter under one mutex + condvar
//! ([`PipeCtrl`]): stage `s` touches request `i`'s hidden slot only after
//! observing `done[s-1] > i` and never again after publishing
//! `done[s] = i + 1` — the mutex provides the happens-before edge, the
//! protocol provides exclusivity, and the fixed batch order makes the
//! schedule deterministic. Per request the stages execute exactly the
//! per-layer float ops of the sequential plane, in the same order
//! ([`Model::decode_layer_range`] loops the same `layer_forward`), so the
//! pipeline is **bit-identical** to `Sequential` for every stage count
//! (`tests/pool_golden.rs` pins stages {1, 2, n_layers}, preemption
//! included). Prefill rounds in `Pipelined` mode reuse the batch plane's
//! request-parallel path unchanged.
//!
//! Flush locality: each submitted flush job is tagged with its layer, and
//! a pipeline stage that finishes its pass drains queued flushes for *its
//! own* layer range (yielding whenever sync work is claimable) — the
//! segments a stage sealed get compressed on the worker that owns those
//! layers, filling the pipeline's drain bubble. Per-stage busy/bubble
//! times are reported through [`BatchExecutor::stage_times`].
//!
//! ## Hybrid per-sweep plane selection ([`ExecMode::Hybrid`])
//!
//! The two parallel planes have complementary sweet spots: the batch plane
//! needs [`MIN_FANOUT`] requests before dispatching pays off, while the
//! pipeline plane parallelizes at batch 1 but pays hand-off overhead per
//! request. Under `Hybrid` the engine picks a plane per decode sweep
//! ([`super::scheduler::PlanePolicy`]: pipeline below a threshold,
//! batch-chunked at or above it, with hysteresis) and calls
//! [`BatchExecutor::set_sweep_plane`] before `run_into`. Both planes run
//! from the same warm pool; their lazily-built per-plane state (the hidden
//! slab, timers, trace slots) lives on the executor and survives switches,
//! and the flush lane is shared pool state — a flush submitted under one
//! plane is drained and joined under the other unchanged. Since each plane
//! is bit-identical to `Sequential`, so is every switch sequence.
//!
//! ## Asynchronous segment flush (submit/join)
//!
//! Decode sweeps append through
//! [`crate::kvcache::LayerKv::append_deferred`]: a buffer that reaches
//! capacity is *sealed*, not compressed inline. At its commit point the
//! engine detaches every sealed (request, layer) pair — in fixed
//! request-serial × layer order — as an owned [`FlushWork`] snapshot and
//! **submits** it ([`BatchExecutor::submit_flush`]) without blocking: the
//! job sits on the pool's flush queue and idle workers pick it up while
//! the engine moves on to the next sweep's emit, reserve, prefill round,
//! and decode step. The engine **joins** each job
//! ([`BatchExecutor::join_flush`]) only at the first point that must
//! observe its result — byte accounting at the sealed request's next
//! commit — so the compression latency hides behind a full sweep of
//! engine work instead of stalling it.
//!
//! Determinism is preserved because the join point is fixed by data
//! dependence, not timing: [`ExecMode::Sequential`] follows the *same*
//! submit/join protocol and simply runs the job inline at the join (the
//! same steal path a `Batched` engine uses when the pool has not started
//! the job yet), so every observation point — attention inputs, `nbytes`
//! at commits, reservations, peaks — sees identical values in both modes
//! and at every pool size (`tests/pool_golden.rs` pins this).
//!
//! **Job priority:** workers always prefer the sync batch (decode and
//! prefill chunk descriptors) over queued flush jobs, so flushes can never
//! starve the critical path; they fill the pool's idle gaps. A panic
//! inside a flush job is captured in its slot and re-raised on the engine
//! thread at the join.
//!
//! GEAR component timings accumulate in worker-thread thread-locals; each
//! job drains its own at completion and the engine folds them back at the
//! deterministic join (or records them directly when it steals the job),
//! so the Fig 3a breakdown still covers off-thread work.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::kvcache::{FlushResult, FlushWork, LayerKv};
use crate::model::config::ModelConfig;
use crate::model::transformer::{DecodeBufs, DecodeSlot, PrefillSlot};
use crate::model::Model;
use crate::trace::{self, Event, EventKind, QualityStaged, Writer};
use crate::util::timing::PhaseTimer;

use super::scheduler::ActiveRequest;

/// How the engine executes a decode sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Whole batch on the engine thread (the reference semantics). No pool
    /// threads are spawned.
    Sequential,
    /// Batch chunked across the persistent worker pool (request-parallel).
    Batched,
    /// Layers sharded into contiguous stages across the pool; each
    /// request's hidden state streams stage-to-stage (layer-parallel), so
    /// decode parallelizes even at batch 1. Bit-identical to `Sequential`
    /// for every stage count.
    Pipelined,
    /// Per-sweep plane selection: the engine consults the scheduler's
    /// [`super::scheduler::PlanePolicy`] at the top of each decode sweep
    /// and dispatches that sweep through either the batch-chunked or the
    /// pipelined plane (small batches pipeline, large batches chunk — see
    /// [`default_hybrid_threshold`]). Both planes run from the same warm
    /// pool and are bit-identical to `Sequential`, so any switch sequence
    /// — including switches with flushes outstanding — is too.
    Hybrid,
}

/// The concrete execution plane one decode sweep dispatches through. Fixed
/// by [`ExecMode`] for the non-hybrid modes; chosen per sweep by the
/// scheduler's plane policy under [`ExecMode::Hybrid`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    /// Request-chunked across the pool (inline below [`MIN_FANOUT`]).
    Batched,
    /// Layer-sharded into contiguous pipeline stages.
    Pipelined,
}

/// Batches smaller than this run inline (still layer-major, just on the
/// engine thread): waking the parked pool and dispatching descriptors costs
/// a few microseconds, which dominates small-model decode steps. 8 is where
/// the parallel win is promised (`bench_throughput -- --compare`); below it
/// the inline path is never slower than the old per-request loop. Also the
/// default switch point for [`ExecMode::Hybrid`]'s plane policy (see
/// [`default_hybrid_threshold`]): below it the batch plane has nothing to
/// fan out, so the pipeline plane is the one that can still parallelize.
pub const MIN_FANOUT: usize = 8;

/// Prefill chunks dispatch at a much lower fan-in than decode steps: one
/// chunk is O(chunk × prompt-so-far) attention work per layer, hundreds of
/// times a decode step, so the dispatch cost amortizes already at two
/// concurrent prefills.
const MIN_PREFILL_FANOUT: usize = 2;

/// Lifecycle of one submitted flush job, guarded by its slot's mutex. The
/// transitions are claim-based: whoever swaps `Queued` out (an idle worker,
/// or the engine stealing at the join) owns the work; everyone else
/// observes `Running`/`Done` and acts accordingly.
enum FlushState {
    /// Submitted, not yet started. Holds the work so the engine can steal
    /// it at the join if no worker got to it first.
    Queued(FlushWork),
    /// A worker claimed the work and is compressing.
    Running,
    /// Finished: the result, the job's drained component timings, its
    /// compression wall time (for the overlap-won metric), and — on traced
    /// runs — the flush-lane trace observation.
    Done { result: FlushResult, timings: PhaseTimer, work_time: Duration, obs: Option<FlushObs> },
    /// Result consumed by [`BatchExecutor::join_flush`] (or the work was
    /// stolen by it); terminal.
    Taken,
    /// The job panicked on a worker; re-raised on the engine at the join.
    Panicked(Box<dyn std::any::Any + Send>),
}

/// Shared slot for one flush job: the pool worker writes the result, the
/// engine waits on `cv` at the join.
struct FlushSlot {
    state: Mutex<FlushState>,
    cv: Condvar,
    /// The model layer whose sealed rows this job compresses. Pure
    /// bookkeeping for the pipeline plane's locality drain — the stage that
    /// owns this layer prefers to run the job itself; results are identical
    /// whoever runs it.
    layer: usize,
}

/// Handle to one submitted flush job, returned by
/// [`BatchExecutor::submit_flush`] and consumed by
/// [`BatchExecutor::join_flush`]. Dropping the ticket without joining
/// abandons the result (the engine does this when the sealed request is
/// preempted or finishes before its next commit — the job's output can no
/// longer matter, and a worker that still runs it writes into the slot
/// harmlessly).
pub struct FlushTicket {
    slot: Arc<FlushSlot>,
}

/// Trace observation of one flush-job run, carried through the job's slot
/// from whichever thread compressed it to the engine's deterministic join
/// (where the [`EventKind::FlushRun`] span and per-matrix
/// [`EventKind::Quality`] records are folded into the journal).
#[derive(Debug)]
pub struct FlushObs {
    /// The run span, attributed to the thread that compressed the job.
    pub run: Event,
    /// Staged quality records for the segment, K then V.
    pub quality: Vec<QualityStaged>,
    /// Stale records discarded before the run started. Always 0 in the
    /// engine flow (quality capture is scoped to attributable
    /// compressions); counted defensively so attribution bugs surface in
    /// [`crate::trace::TraceSummary::quality_dropped`] instead of
    /// mislabelling records.
    pub stale: u64,
}

/// Everything [`BatchExecutor::join_flush`] returns for one joined job.
pub struct FlushJoined {
    /// The compressed segment.
    pub result: FlushResult,
    /// Wall time the join call itself blocked (engine-side stall).
    pub stalled: Duration,
    /// Compression wall time that completed off the engine's critical path
    /// (the overlap win); zero when the engine stole and ran the job
    /// inline.
    pub hidden: Duration,
    /// Trace observation of the run (traced runs only).
    pub obs: Option<FlushObs>,
}

/// Run a queued flush job on a pool worker: claim the work (skipping if the
/// engine already stole it), compress, publish the result, and wake any
/// joiner. Runs outside the pool-control lock so sync dispatches and other
/// flushes proceed concurrently. With `traced` set, the compression runs
/// under a quality-capture scope and its span + staged quality ride the
/// slot to the join.
fn service_flush(slot: &FlushSlot, traced: bool) {
    let work = {
        let mut st = slot.state.lock().unwrap();
        match std::mem::replace(&mut *st, FlushState::Running) {
            FlushState::Queued(work) => work,
            other => {
                // Already stolen/served; put the observed state back.
                *st = other;
                return;
            }
        }
    };
    let t0 = Instant::now();
    let stale = if traced { trace::take_staged_quality().len() as u64 } else { 0 };
    if traced {
        trace::set_quality_capture(true);
    }
    let span_start = if traced { trace::now_ns() } else { 0 };
    let res = catch_unwind(AssertUnwindSafe(|| work.compress()));
    if traced {
        trace::set_quality_capture(false);
    }
    let obs = traced.then(|| FlushObs {
        run: Event {
            t_ns: span_start,
            dur_ns: trace::now_ns().saturating_sub(span_start),
            writer: trace::thread_writer(),
            kind: EventKind::FlushRun { layer: slot.layer as u32 },
        },
        quality: trace::take_staged_quality(),
        stale,
    });
    let timings = crate::gear::take_phase_timings();
    let work_time = t0.elapsed();
    let mut st = slot.state.lock().unwrap();
    *st = match res {
        Ok(result) => FlushState::Done { result, timings, work_time, obs },
        Err(p) => FlushState::Panicked(p),
    };
    slot.cv.notify_all();
}

/// Live pool-worker threads across the process (observability; the
/// lifecycle test pins that dropping an [`super::engine::Engine`] joins its
/// workers).
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Number of pool worker threads currently alive in this process.
pub fn live_pool_workers() -> usize {
    LIVE_WORKERS.load(Ordering::SeqCst)
}

/// Resolve the pool size for [`ExecMode::Batched`]: the `GEAR_POOL_THREADS`
/// environment variable when set to a positive integer, otherwise the host
/// parallelism. CI runs the test suite at both 1 and 4 so the single-worker
/// and multi-worker dispatch paths stay exercised.
pub fn default_pool_threads() -> usize {
    let avail = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("GEAR_POOL_THREADS") {
        Ok(s) => s.trim().parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or_else(avail),
        Err(_) => avail(),
    }
}

/// Resolve the stage count for [`ExecMode::Pipelined`]: the
/// `GEAR_PIPELINE_STAGES` environment variable when set to a positive
/// integer, otherwise one stage per pool worker. The effective count is
/// further clamped to the model's layer count at dispatch time (a stage
/// must own at least one layer); the token stream is bit-identical for
/// every value.
pub fn default_pipeline_stages(workers: usize) -> usize {
    match std::env::var("GEAR_PIPELINE_STAGES") {
        Ok(s) => s.trim().parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or(workers),
        Err(_) => workers,
    }
    .max(1)
}

/// Resolve the decode-batch threshold for [`ExecMode::Hybrid`]'s plane
/// policy: the `GEAR_HYBRID_THRESHOLD` environment variable when set to a
/// positive integer, otherwise [`MIN_FANOUT`]. Batches at or above the
/// threshold dispatch through the batch-chunked plane; smaller batches
/// pipeline (see [`super::scheduler::PlanePolicy`] for the hysteresis
/// rules). Results are bit-identical for every value — the threshold only
/// moves work between two bit-identical planes.
pub fn default_hybrid_threshold() -> usize {
    match std::env::var("GEAR_HYBRID_THRESHOLD") {
        Ok(s) => s.trim().parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or(MIN_FANOUT),
        Err(_) => MIN_FANOUT,
    }
}

/// Partition `n_layers` into `stages` contiguous near-equal ranges
/// (`stages <= n_layers`); the first `n_layers % stages` stages take one
/// extra layer. Fixed by the configuration, never by timing.
fn stage_ranges(n_layers: usize, stages: usize) -> Vec<(usize, usize)> {
    debug_assert!(stages >= 1 && stages <= n_layers);
    let (base, extra) = (n_layers / stages, n_layers % stages);
    let mut ranges = Vec::with_capacity(stages);
    let mut start = 0;
    for s in 0..stages {
        let len = base + usize::from(s < extra);
        ranges.push((start, start + len));
        start += len;
    }
    ranges
}

/// The pipeline hand-off: one progress counter per stage under a single
/// mutex. `done[s]` is the number of requests stage `s` has fully
/// processed; stage `s` may touch request `i`'s hidden slot only in the
/// window between observing `done[s-1] > i` and publishing
/// `done[s] = i + 1`. The mutex acquire/release pair gives the
/// happens-before edge that makes the slot hand-off sound; the counters
/// make it exclusive.
struct PipeCtrl {
    done: Mutex<Vec<usize>>,
    cv: Condvar,
}

impl PipeCtrl {
    fn new(stages: usize) -> PipeCtrl {
        PipeCtrl { done: Mutex::new(vec![0; stages]), cv: Condvar::new() }
    }

    /// Block until `upstream` has published request `i`; returns the time
    /// spent waiting (this stage's hand-off bubble).
    fn wait_upstream(&self, upstream: usize, i: usize) -> Duration {
        let t0 = Instant::now();
        let mut g = self.done.lock().unwrap();
        while g[upstream] <= i {
            g = self.cv.wait(g).unwrap();
        }
        t0.elapsed()
    }

    /// Publish that `stage` finished request `i`, handing the hidden slot
    /// to the downstream stage.
    fn publish(&self, stage: usize, i: usize) {
        let mut g = self.done.lock().unwrap();
        debug_assert_eq!(g[stage], i, "pipeline stage published out of order");
        g[stage] = i + 1;
        drop(g);
        self.cv.notify_all();
    }

    /// Force `stage`'s counter to `total`. Called from the poison guard on
    /// unwind so a panicking stage can never strand downstream waiters:
    /// they terminate on garbage hidden states whose results are discarded
    /// when `run_jobs` re-raises the panic on the dispatcher. No-op on the
    /// normal path (the counter is already there).
    fn force_complete(&self, stage: usize, total: usize) {
        let mut g = self.done.lock().unwrap();
        if g[stage] < total {
            g[stage] = total;
            drop(g);
            self.cv.notify_all();
        }
    }
}

/// Unwind guard for one pipeline stage; see [`PipeCtrl::force_complete`].
struct StagePoisonGuard<'a> {
    ctrl: &'a PipeCtrl,
    stage: usize,
    total: usize,
}

impl Drop for StagePoisonGuard<'_> {
    fn drop(&mut self) {
        self.ctrl.force_complete(self.stage, self.total);
    }
}

/// Raw-pointer view of the executor's pooled per-request hidden states,
/// shared by every pipeline stage. Exclusivity per slot comes from the
/// [`PipeCtrl`] hand-off protocol, not from the type — hence the unsafe
/// accessor.
struct HiddenSlab {
    ptr: *mut Vec<f32>,
    len: usize,
}

// SAFETY: slots are plain `Vec<f32>` (Send); the hand-off protocol
// guarantees no two threads access a slot concurrently, and every transfer
// goes through the `PipeCtrl` mutex (acquire/release ordering).
unsafe impl Send for HiddenSlab {}
unsafe impl Sync for HiddenSlab {}

impl HiddenSlab {
    /// # Safety
    /// The caller must hold the hand-off token for slot `i`: it observed
    /// `done[s-1] > i` (or is stage 0) and has not yet published
    /// `done[s] = i + 1`.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slot(&self, i: usize) -> &mut Vec<f32> {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

/// A dispatched job batch: workers call `f(job_index, &mut pinned_bufs)`
/// for every index in `0..n_jobs`. The reference is transmuted to `'static`
/// only while [`WorkerPool::run_jobs`] blocks — see the safety argument
/// there.
#[derive(Clone, Copy)]
struct JobRef(&'static (dyn Fn(usize, &mut DecodeBufs) + Sync));

/// Shared pool state: one mutex-guarded control block plus two condvars
/// (workers park on `work_cv`; the dispatcher parks on `done_cv`).
struct PoolCtrl {
    /// The current job batch, present only while a dispatch is in flight.
    job: Option<JobRef>,
    /// Next unclaimed job index.
    next: usize,
    /// Total jobs in the current batch.
    n_jobs: usize,
    /// Jobs finished (claimed *and* run) in the current batch.
    done: usize,
    /// Set once by `Drop`; workers exit on observing it.
    shutdown: bool,
    /// First panic payload captured from a job, re-raised on the dispatcher.
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Submitted flush jobs awaiting a worker, oldest first. Strictly lower
    /// priority than the sync batch: a worker only pops from here when no
    /// sync job index is claimable, so flushes fill idle gaps and can never
    /// starve decode or prefill dispatches. (Jobs still queued at the join
    /// are stolen and run inline by the engine; jobs still queued at
    /// shutdown are dropped — their tickets are gone too.)
    flushes: VecDeque<Arc<FlushSlot>>,
}

struct PoolShared {
    ctrl: Mutex<PoolCtrl>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Whether this executor's current run is traced. Workers read it with
    /// one relaxed load before servicing a queued flush — the only tracing
    /// cost on an untraced worker's path (sync dispatches read the
    /// executor-side bool instead, captured into each job closure).
    trace_on: AtomicBool,
}

/// A fixed-size persistent worker pool. Threads are spawned once, park on a
/// condvar when idle, and each pins one [`DecodeBufs`] for its lifetime.
/// Dropping the pool signals shutdown and joins every worker.
struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

/// Erase the dispatch-scoped lifetime of a job closure.
///
/// # Safety
/// The returned reference is only valid while `f` is. `run_jobs` installs
/// it under the control lock, blocks until every job has finished running,
/// and clears it before returning — so no worker can observe the reference
/// after the borrow it came from expires. This is the classic scoped-pool
/// pattern (`std::thread::scope` does the same erasure internally).
unsafe fn erase(
    f: &(dyn Fn(usize, &mut DecodeBufs) + Sync),
) -> &'static (dyn Fn(usize, &mut DecodeBufs) + Sync) {
    std::mem::transmute::<
        &(dyn Fn(usize, &mut DecodeBufs) + Sync),
        &'static (dyn Fn(usize, &mut DecodeBufs) + Sync),
    >(f)
}

impl WorkerPool {
    fn new(threads: usize, cfg: ModelConfig) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            ctrl: Mutex::new(PoolCtrl {
                job: None,
                next: 0,
                n_jobs: 0,
                done: 0,
                shutdown: false,
                panic: None,
                flushes: VecDeque::new(),
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            trace_on: AtomicBool::new(false),
        });
        let handles = (0..threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                // Count the worker live from the spawning thread so the
                // observable count is already exact when `new` returns
                // (the worker itself decrements on exit).
                LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("gear-exec-{i}"))
                    .spawn(move || worker_main(shared, cfg, i))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Run `n_jobs` jobs on the pool and block until all have finished.
    /// Workers claim indices in order; `f` must be safe to call
    /// concurrently for distinct indices (each job owns disjoint data). A
    /// panic inside any job is captured and re-raised here after the batch
    /// drains, so worker threads survive poisoned sweeps.
    fn run_jobs(&self, n_jobs: usize, f: &(dyn Fn(usize, &mut DecodeBufs) + Sync)) {
        if n_jobs == 0 {
            return;
        }
        // SAFETY: cleared below before this borrow of `f` ends; see `erase`.
        let job = JobRef(unsafe { erase(f) });
        {
            let mut g = self.shared.ctrl.lock().unwrap();
            debug_assert!(g.job.is_none(), "overlapping dispatch");
            g.job = Some(job);
            g.next = 0;
            g.done = 0;
            g.n_jobs = n_jobs;
        }
        self.shared.work_cv.notify_all();
        let mut g = self.shared.ctrl.lock().unwrap();
        while g.done < g.n_jobs {
            g = self.shared.done_cv.wait(g).unwrap();
        }
        g.job = None;
        g.next = 0;
        g.n_jobs = 0;
        g.done = 0;
        let panic = g.panic.take();
        drop(g);
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.ctrl.lock().unwrap();
            g.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(shared: Arc<PoolShared>, cfg: ModelConfig, idx: usize) {
    // Declare this thread's trace track once; allocates nothing — the
    // thread-local event ring only materializes if a traced job emits.
    trace::set_thread_writer(Writer::Worker(idx as u16));
    // The matching increment happens on the spawning thread (see
    // `WorkerPool::new`); the guard decrements on any exit path, and
    // `Drop for WorkerPool` joins the thread *after* that runs — so once
    // the pool is dropped the count is exact, no polling needed.
    struct LiveGuard;
    impl Drop for LiveGuard {
        fn drop(&mut self) {
            LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _live = LiveGuard;

    // The worker's pinned scratch: allocated once here, reused by every job
    // this thread ever runs. Buffers inside grow to high-water marks and
    // are fully overwritten before use, so reuse cannot change results.
    let mut bufs = DecodeBufs::new(&cfg);
    // Work a worker can pick up: an index of the current sync batch, or a
    // queued asynchronous flush job.
    enum Work {
        Sync(JobRef, usize),
        Flush(Arc<FlushSlot>),
    }
    loop {
        let work = {
            let mut g = shared.ctrl.lock().unwrap();
            loop {
                if g.shutdown {
                    return;
                }
                // Sync batch first — flush jobs must never delay a decode
                // or prefill dispatch that has claimable chunks.
                if let Some(job) = g.job {
                    if g.next < g.n_jobs {
                        let idx = g.next;
                        g.next += 1;
                        break Work::Sync(job, idx);
                    }
                }
                if let Some(slot) = g.flushes.pop_front() {
                    break Work::Flush(slot);
                }
                g = shared.work_cv.wait(g).unwrap();
            }
        };
        match work {
            Work::Sync(job, idx) => {
                let res = catch_unwind(AssertUnwindSafe(|| (job.0)(idx, &mut bufs)));
                let mut g = shared.ctrl.lock().unwrap();
                if let Err(p) = res {
                    if g.panic.is_none() {
                        g.panic = Some(p);
                    }
                }
                g.done += 1;
                if g.done >= g.n_jobs {
                    shared.done_cv.notify_one();
                }
            }
            // Flush jobs publish into their own slot (panics included) and
            // never touch the sync batch counters.
            Work::Flush(slot) => {
                let traced = shared.trace_on.load(Ordering::Relaxed);
                service_flush(&slot, traced);
            }
        }
    }
}

/// One contiguous slice of a decode sweep, handed to a pool worker: the
/// requests to advance, the per-request logits slots to fill, and the slot
/// for the worker's component timings.
struct DecodeChunk<'a, 'b> {
    reqs: &'a mut [&'b mut ActiveRequest],
    outs: &'a mut [Vec<f32>],
    timer: &'a mut PhaseTimer,
    /// Slot for the worker's drained trace events (traced runs only).
    trace: &'a mut Vec<Event>,
}

/// One pipeline stage of a decode sweep, handed to a pool worker: a
/// contiguous layer range, every request's cache slice for exactly those
/// layers (batch order), and — for the last stage only — the logits slots.
struct StageTask<'a> {
    stage: usize,
    /// Global `[start, end)` layer range this stage owns.
    range: (usize, usize),
    /// Per-request disjoint slices of `cache.layers[range]`, batch order.
    layers: Vec<&'a mut [Box<dyn LayerKv>]>,
    /// `Some` only on the last stage, which finishes each hidden state
    /// into its logits slot.
    outs: Option<&'a mut [Vec<f32>]>,
    timer: &'a mut PhaseTimer,
    /// `(busy, bubble)` output slot: compute time vs hand-off wait time.
    times: &'a mut (Duration, Duration),
    /// Slot for the stage's drained trace events (traced runs only).
    trace: &'a mut Vec<Event>,
}

/// Executes batched decode steps, prefill rounds, and asynchronous flush
/// jobs (submit/join) for the engine.
pub struct BatchExecutor {
    mode: ExecMode,
    /// Pool size (1 for `Sequential`, which never dispatches).
    workers: usize,
    /// Configured pipeline stage count (`Pipelined`/`Hybrid`; clamped to
    /// the layer count at dispatch).
    stages: usize,
    /// The persistent pool; `None` in `Sequential` mode.
    pool: Option<WorkerPool>,
    /// Engine-thread scratch, used for inline (undispatched) execution.
    bufs: DecodeBufs,
    /// Per-job timing slots, reused across dispatches; folded back into
    /// the engine thread's accumulator in job order after each batch.
    timers: Vec<PhaseTimer>,
    /// Pooled per-request hidden states for the pipeline plane (the slab
    /// behind [`HiddenSlab`]); grows to the largest batch seen.
    pipe_hidden: Vec<Vec<f32>>,
    /// Per-stage `(busy, bubble)` of the most recent pipelined dispatch;
    /// the engine folds these into [`super::metrics::EngineMetrics`].
    stage_times: Vec<(Duration, Duration)>,
    /// Tracing enabled for dispatches from this executor. Cached as a
    /// plain bool so the sync hot path does not even pay an atomic load;
    /// mirrored into [`PoolShared::trace_on`] for the flush lane.
    trace_on: bool,
    /// Per-chunk / per-stage event slots, reused across dispatches and
    /// folded into `pending_events` in chunk order after each batch.
    chunk_trace: Vec<Vec<Event>>,
    /// Worker/stage events folded from dispatches since the engine last
    /// drained them via [`Self::take_trace_events`].
    pending_events: Vec<Event>,
    /// The plane the next decode sweep dispatches through under
    /// [`ExecMode::Hybrid`] (set per sweep via [`Self::set_sweep_plane`];
    /// ignored by the fixed modes). Both planes' lazily-built state
    /// (`pipe_hidden`, timers, trace slots) lives on this executor and the
    /// flush lane is shared pool state, so switching costs nothing and a
    /// flush submitted under one plane joins under the other unchanged.
    sweep_plane: Plane,
}

impl BatchExecutor {
    /// `threads` overrides the pool size for the pooled modes; `None` falls
    /// back to [`default_pool_threads`] (`GEAR_POOL_THREADS` / host
    /// parallelism). `stages` overrides the `Pipelined` stage count; `None`
    /// falls back to [`default_pipeline_stages`] (`GEAR_PIPELINE_STAGES` /
    /// one per worker). `Sequential` spawns no threads.
    pub fn new(
        model: &Model,
        mode: ExecMode,
        threads: Option<usize>,
        stages: Option<usize>,
    ) -> BatchExecutor {
        let workers = match mode {
            ExecMode::Sequential => 1,
            ExecMode::Batched | ExecMode::Pipelined | ExecMode::Hybrid => {
                threads.unwrap_or_else(default_pool_threads).max(1)
            }
        };
        let stages = match mode {
            ExecMode::Pipelined | ExecMode::Hybrid => {
                stages.unwrap_or_else(|| default_pipeline_stages(workers))
            }
            _ => 1,
        }
        .max(1);
        let pool = match mode {
            ExecMode::Sequential => None,
            ExecMode::Batched | ExecMode::Pipelined | ExecMode::Hybrid => {
                Some(WorkerPool::new(workers, *model.config()))
            }
        };
        BatchExecutor {
            mode,
            workers,
            stages,
            pool,
            bufs: DecodeBufs::new(model.config()),
            timers: Vec::new(),
            pipe_hidden: Vec::new(),
            stage_times: Vec::new(),
            trace_on: false,
            chunk_trace: Vec::new(),
            pending_events: Vec::new(),
            sweep_plane: Plane::Batched,
        }
    }

    /// Select the plane the next decode sweep dispatches through. Only
    /// meaningful under [`ExecMode::Hybrid`] (the fixed modes ignore it);
    /// called by the engine once per sweep after consulting the
    /// scheduler's plane policy, before [`Self::run_into`].
    pub fn set_sweep_plane(&mut self, plane: Plane) {
        self.sweep_plane = plane;
    }

    /// Enable or disable tracing for subsequent dispatches. Sets this
    /// executor's cached flag (read once per dispatch, no atomics on the
    /// sync path) and the pool's shared flag (one relaxed load per
    /// serviced flush job).
    pub fn set_trace(&mut self, on: bool) {
        self.trace_on = on;
        if let Some(pool) = &self.pool {
            pool.shared.trace_on.store(on, Ordering::Relaxed);
        }
    }

    /// Drain worker/stage events folded from dispatches since the last
    /// call. The engine folds these into its tracer at fixed points
    /// (after each decode/prefill dispatch), keeping journal order
    /// deterministic.
    pub fn take_trace_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.pending_events)
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Pool size this executor dispatches across.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Configured pipeline stage count (1 unless `Pipelined` or `Hybrid`).
    pub fn stages(&self) -> usize {
        self.stages
    }

    /// Per-stage `(busy, bubble)` durations of the most recent pipelined
    /// decode dispatch: compute time vs time spent waiting on the upstream
    /// hand-off. Empty when the last sweep ran inline or non-pipelined.
    pub fn stage_times(&self) -> &[(Duration, Duration)] {
        &self.stage_times
    }

    /// Advance every request in `batch` one decode step; logits land in
    /// `out` in `batch` order regardless of which worker produced them.
    /// `out` is resized to the batch and its inner vectors are reused
    /// across sweeps (the engine keeps one pooled instance), so a steady
    /// decode sweep performs no per-request allocation.
    pub fn run_into(
        &mut self,
        model: &Model,
        batch: &mut [&mut ActiveRequest],
        out: &mut Vec<Vec<f32>>,
    ) {
        let b = batch.len();
        out.resize_with(b, Vec::new);
        self.stage_times.clear();
        if b == 0 {
            return;
        }
        // Resolve the effective plane: fixed by the mode, except under
        // Hybrid where the engine selected it for this sweep. `Sequential`
        // has no pool, so its batch-plane dispatch below always takes the
        // inline path — the reference semantics.
        let plane = match self.mode {
            ExecMode::Pipelined => Plane::Pipelined,
            ExecMode::Hybrid => self.sweep_plane,
            ExecMode::Sequential | ExecMode::Batched => Plane::Batched,
        };
        if plane == Plane::Pipelined {
            self.run_pipelined(model, batch, out);
            return;
        }
        let traced = self.trace_on;
        let pool = match &self.pool {
            Some(pool) if b >= MIN_FANOUT => pool,
            _ => {
                let span_start = if traced { trace::now_ns() } else { 0 };
                let mut slots: Vec<DecodeSlot> = batch
                    .iter_mut()
                    .map(|a| DecodeSlot { token: a.next_token, pos: a.pos, cache: &mut a.cache })
                    .collect();
                model.decode_batch_into(&mut slots, &mut self.bufs, out);
                if traced {
                    self.pending_events.push(Event {
                        t_ns: span_start,
                        dur_ns: trace::now_ns().saturating_sub(span_start),
                        writer: Writer::Engine,
                        kind: EventKind::Chunk { n_seqs: b as u32 },
                    });
                }
                return;
            }
        };

        // Contiguous chunk descriptors in batch order; workers claim them
        // by index and write into disjoint output slices, so the reduction
        // order is fixed by construction.
        let chunk = b.div_ceil(self.workers.min(b));
        let n_chunks = b.div_ceil(chunk);
        self.timers.clear();
        self.timers.resize_with(n_chunks, PhaseTimer::new);
        self.chunk_trace.clear();
        self.chunk_trace.resize_with(n_chunks, Vec::new);
        let tasks: Vec<Mutex<Option<DecodeChunk>>> = batch
            .chunks_mut(chunk)
            .zip(out.chunks_mut(chunk))
            .zip(self.timers.iter_mut().zip(self.chunk_trace.iter_mut()))
            .map(|((reqs, outs), (timer, trace))| {
                Mutex::new(Some(DecodeChunk { reqs, outs, timer, trace }))
            })
            .collect();
        pool.run_jobs(tasks.len(), &|i, bufs| {
            let DecodeChunk { reqs, outs, timer, trace: tr } =
                tasks[i].lock().unwrap().take().expect("decode chunk claimed twice");
            let span_start = if traced { trace::now_ns() } else { 0 };
            let n_seqs = reqs.len() as u32;
            let mut slots: Vec<DecodeSlot> = reqs
                .iter_mut()
                .map(|a| DecodeSlot { token: a.next_token, pos: a.pos, cache: &mut a.cache })
                .collect();
            model.decode_batch_into(&mut slots, bufs, outs);
            *timer = crate::gear::take_phase_timings();
            if traced {
                trace::emit_thread_span(None, EventKind::Chunk { n_seqs }, span_start);
                *tr = trace::drain_thread();
            }
        });
        for t in &self.timers {
            crate::gear::merge_phase_timings(t);
        }
        for t in &mut self.chunk_trace {
            self.pending_events.append(t);
        }
    }

    /// One pipelined decode sweep: layers partitioned into contiguous
    /// stages, each request's hidden state streamed stage-to-stage through
    /// [`PipeCtrl`]. Stage `s` runs request `i` while stage `s+1` runs
    /// request `i-1`, so even a single request parallelizes — there is no
    /// minimum fan-out gate on this plane. With one effective stage (or no
    /// pool) the sweep runs inline, which is the sequential plane's math
    /// verbatim.
    fn run_pipelined(
        &mut self,
        model: &Model,
        batch: &mut [&mut ActiveRequest],
        out: &mut [Vec<f32>],
    ) {
        let b = batch.len();
        let c = *model.config();
        let stages = self.stages.min(c.n_layers).max(1);
        let traced = self.trace_on;
        let pool = match &self.pool {
            Some(pool) if stages > 1 => pool,
            _ => {
                let span_start = if traced { trace::now_ns() } else { 0 };
                let mut slots: Vec<DecodeSlot> = batch
                    .iter_mut()
                    .map(|a| DecodeSlot { token: a.next_token, pos: a.pos, cache: &mut a.cache })
                    .collect();
                model.decode_batch_into(&mut slots, &mut self.bufs, out);
                if traced {
                    self.pending_events.push(Event {
                        t_ns: span_start,
                        dur_ns: trace::now_ns().saturating_sub(span_start),
                        writer: Writer::Engine,
                        kind: EventKind::Chunk { n_seqs: b as u32 },
                    });
                }
                return;
            }
        };

        let ranges = stage_ranges(c.n_layers, stages);
        // Stage 0's embed inputs, snapshotted so the stage closures only
        // share the requests' cache slices mutably.
        let steps: Vec<(u32, usize)> = batch.iter().map(|a| (a.next_token, a.pos)).collect();

        // The hidden slab is sized on the dispatcher so no stage ever
        // reallocates a slot another stage holds a pointer into.
        if self.pipe_hidden.len() < b {
            self.pipe_hidden.resize_with(b, Vec::new);
        }
        for x in self.pipe_hidden.iter_mut().take(b) {
            x.resize(c.d_model, 0.0);
        }
        let slab = HiddenSlab { ptr: self.pipe_hidden.as_mut_ptr(), len: b };

        // Split every request's cache layers into one disjoint slice per
        // stage, gathered stage-major: stage `s` of request `i` and stage
        // `s'` of request `i'` can never alias.
        let mut stage_layers: Vec<Vec<&mut [Box<dyn LayerKv>]>> =
            (0..stages).map(|_| Vec::with_capacity(b)).collect();
        for a in batch.iter_mut() {
            let mut rest: &mut [Box<dyn LayerKv>] = &mut a.cache.layers;
            for (si, &(start, end)) in ranges.iter().enumerate() {
                let (head, tail) = rest.split_at_mut(end - start);
                stage_layers[si].push(head);
                rest = tail;
            }
        }

        self.timers.clear();
        self.timers.resize_with(stages, PhaseTimer::new);
        self.stage_times.resize(stages, (Duration::ZERO, Duration::ZERO));
        self.chunk_trace.clear();
        self.chunk_trace.resize_with(stages, Vec::new);

        let ctrl = PipeCtrl::new(stages);
        let mut outs = Some(&mut out[..b]);
        let tasks: Vec<Mutex<Option<StageTask>>> = stage_layers
            .into_iter()
            .zip(self.timers.iter_mut())
            .zip(self.stage_times.iter_mut().zip(self.chunk_trace.iter_mut()))
            .enumerate()
            .map(|(s, ((layers, timer), (times, trace)))| {
                Mutex::new(Some(StageTask {
                    stage: s,
                    range: ranges[s],
                    layers,
                    outs: if s + 1 == stages { outs.take() } else { None },
                    timer,
                    times,
                    trace,
                }))
            })
            .collect();

        let shared = &pool.shared;
        pool.run_jobs(stages, &|s, bufs| {
            let StageTask { stage, range, mut layers, mut outs, timer, times, trace: tr } =
                tasks[s].lock().unwrap().take().expect("pipeline stage claimed twice");
            let span_start = if traced { trace::now_ns() } else { 0 };
            // On unwind, mark this stage complete so downstream stages
            // terminate instead of waiting forever; their garbage outputs
            // are discarded when `run_jobs` re-raises the panic.
            let _poison = StagePoisonGuard { ctrl: &ctrl, stage, total: b };
            let t0 = Instant::now();
            let mut waited = Duration::ZERO;
            for i in 0..b {
                if stage > 0 {
                    waited += ctrl.wait_upstream(stage - 1, i);
                }
                // SAFETY: we hold slot `i`'s hand-off token — upstream
                // published it (or we are stage 0) and we have not yet.
                let x = unsafe { slab.slot(i) };
                if stage == 0 {
                    let (token, pos) = steps[i];
                    model.embed_token_into(token, pos, x);
                }
                model.decode_layer_range(range.0, &mut *layers[i], x, bufs);
                if let Some(outs) = outs.as_deref_mut() {
                    model.finish_logits_into(x, bufs, &mut outs[i]);
                }
                ctrl.publish(stage, i);
            }
            *timer = crate::gear::take_phase_timings();
            let wall = t0.elapsed();
            *times = (wall.saturating_sub(waited), waited);
            if traced {
                // Two spans per stage per sweep: aggregate bubble (upstream
                // hand-off waits) then aggregate busy. Magnitudes are exact;
                // the placement (bubble-then-busy) is a summary — the real
                // waits interleave per request.
                let w = Writer::Stage(stage as u16);
                let st16 = stage as u16;
                let waited_ns = waited.as_nanos() as u64;
                let end = trace::now_ns();
                trace::emit_thread_at(
                    Some(w),
                    EventKind::StageSpan { stage: st16, busy: false },
                    span_start,
                    waited_ns,
                );
                trace::emit_thread_at(
                    Some(w),
                    EventKind::StageSpan { stage: st16, busy: true },
                    span_start.saturating_add(waited_ns),
                    end.saturating_sub(span_start).saturating_sub(waited_ns),
                );
                *tr = trace::drain_thread();
            }
            // Locality drain: while later stages are still draining the
            // pipeline tail, compress any queued flush whose layer this
            // stage owns — on the worker whose caches those are. Strictly
            // lower priority than sync work: yield the moment a sync job
            // index is claimable (e.g. a worker-starved stage of this very
            // dispatch). The last stage skips the drain — it *is* the
            // critical path. Flush jobs are pure and joined at fixed
            // points, so who runs them cannot change any result.
            if stage + 1 < stages {
                loop {
                    let slot = {
                        let mut g = shared.ctrl.lock().unwrap();
                        if g.job.is_some() && g.next < g.n_jobs {
                            break;
                        }
                        let pos = g
                            .flushes
                            .iter()
                            .position(|f| (range.0..range.1).contains(&f.layer));
                        match pos {
                            Some(p) => g.flushes.remove(p).expect("indexed flush slot"),
                            None => break,
                        }
                    };
                    service_flush(&slot, traced);
                }
            }
        });
        for t in &self.timers {
            crate::gear::merge_phase_timings(t);
        }
        for t in &mut self.chunk_trace {
            self.pending_events.append(t);
        }
    }

    /// Advance every slot's prefill by one chunk. Results land in each
    /// slot's [`crate::model::PrefillState`], so there is nothing to
    /// reduce; slots are split into contiguous chunk descriptors exactly
    /// like decode. Every slot's chunk touches only its own state, so the
    /// dispatched round is bit-identical to the inline one. (No GEAR
    /// component work happens in the chunk jobs — chunks accumulate exact
    /// f32 K/V, and the prompt compresses later in `Model::commit_prefill`
    /// on the engine thread — so there are no timings to fold back.)
    pub fn run_prefill(&mut self, model: &Model, slots: &mut [PrefillSlot<'_>]) {
        let b = slots.len();
        if b == 0 {
            return;
        }
        let traced = self.trace_on;
        let pool = match &self.pool {
            Some(pool) if b >= MIN_PREFILL_FANOUT => pool,
            _ => {
                let span_start = if traced { trace::now_ns() } else { 0 };
                model.prefill_chunk_batch(slots, &mut self.bufs);
                if traced {
                    self.pending_events.push(Event {
                        t_ns: span_start,
                        dur_ns: trace::now_ns().saturating_sub(span_start),
                        writer: Writer::Engine,
                        kind: EventKind::Chunk { n_seqs: b as u32 },
                    });
                }
                return;
            }
        };
        let chunk = b.div_ceil(self.workers.min(b));
        let n_chunks = b.div_ceil(chunk);
        self.chunk_trace.clear();
        self.chunk_trace.resize_with(n_chunks, Vec::new);
        let tasks: Vec<Mutex<Option<(&mut [PrefillSlot], &mut Vec<Event>)>>> = slots
            .chunks_mut(chunk)
            .zip(self.chunk_trace.iter_mut())
            .map(|(part, tr)| Mutex::new(Some((part, tr))))
            .collect();
        pool.run_jobs(tasks.len(), &|i, bufs| {
            let (part, tr) =
                tasks[i].lock().unwrap().take().expect("prefill chunk claimed twice");
            let span_start = if traced { trace::now_ns() } else { 0 };
            let n_seqs = part.len() as u32;
            model.prefill_chunk_batch(part, bufs);
            if traced {
                trace::emit_thread_span(None, EventKind::Chunk { n_seqs }, span_start);
                *tr = trace::drain_thread();
            }
        });
        for t in &mut self.chunk_trace {
            self.pending_events.append(t);
        }
    }

    /// Submit one detached flush job for asynchronous compression and
    /// return its ticket. Never blocks: in the pooled modes the job joins
    /// the pool's flush queue, where idle workers pick it up between (and
    /// with strictly lower priority than) sync dispatches — in `Pipelined`
    /// mode the stage that owns `layer` preferentially drains it; in
    /// `Sequential` mode the job simply waits in its slot for
    /// [`Self::join_flush`] to run it inline — the same protocol, so every
    /// mode observes identical state at every point. `layer` is the model
    /// layer whose sealed rows the job compresses (locality bookkeeping
    /// only).
    pub fn submit_flush(&mut self, work: FlushWork, layer: usize) -> FlushTicket {
        let slot = Arc::new(FlushSlot {
            state: Mutex::new(FlushState::Queued(work)),
            cv: Condvar::new(),
            layer,
        });
        if let Some(pool) = &self.pool {
            let mut g = pool.shared.ctrl.lock().unwrap();
            g.flushes.push_back(Arc::clone(&slot));
            drop(g);
            pool.shared.work_cv.notify_one();
        }
        FlushTicket { slot }
    }

    /// Join one submitted flush job, blocking until its result is
    /// available: still-queued work is *stolen* and compressed inline on
    /// the calling thread (always the case in `Sequential` mode), running
    /// work is waited on, finished work returns immediately. Worker-side
    /// component timings fold into the calling thread's accumulator here —
    /// at the engine's deterministic join order — and a worker-side panic
    /// re-raises here. On traced runs the returned [`FlushObs`] carries
    /// the run span and the segment's staged quality records, whichever
    /// thread compressed it.
    pub fn join_flush(&mut self, ticket: FlushTicket) -> FlushJoined {
        let traced = self.trace_on;
        let t0 = Instant::now();
        let mut st = ticket.slot.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, FlushState::Taken) {
                FlushState::Queued(work) => {
                    // Steal: no worker started it. Compress inline; the
                    // component timings land directly in this thread's
                    // accumulator, exactly like the old blocking flush.
                    drop(st);
                    let stale =
                        if traced { trace::take_staged_quality().len() as u64 } else { 0 };
                    if traced {
                        trace::set_quality_capture(true);
                    }
                    let span_start = if traced { trace::now_ns() } else { 0 };
                    let result = work.compress();
                    if traced {
                        trace::set_quality_capture(false);
                    }
                    let obs = traced.then(|| FlushObs {
                        run: Event {
                            t_ns: span_start,
                            dur_ns: trace::now_ns().saturating_sub(span_start),
                            writer: Writer::Engine,
                            kind: EventKind::FlushRun { layer: ticket.slot.layer as u32 },
                        },
                        quality: trace::take_staged_quality(),
                        stale,
                    });
                    return FlushJoined {
                        result,
                        stalled: t0.elapsed(),
                        hidden: Duration::ZERO,
                        obs,
                    };
                }
                FlushState::Running => {
                    *st = FlushState::Running;
                    st = ticket.slot.cv.wait(st).unwrap();
                }
                FlushState::Done { result, timings, work_time, obs } => {
                    crate::gear::merge_phase_timings(&timings);
                    let stalled = t0.elapsed();
                    return FlushJoined {
                        result,
                        stalled,
                        hidden: work_time.saturating_sub(stalled),
                        obs,
                    };
                }
                FlushState::Taken => unreachable!("flush ticket joined twice"),
                FlushState::Panicked(p) => resume_unwind(p),
            }
        }
    }
}
