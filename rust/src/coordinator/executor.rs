//! The execution plane: one batched decode step — and one batched round of
//! prefill chunks — over the whole active set.
//!
//! The executor owns no policy. It receives the active requests in engine
//! order, runs [`Model::decode_batch_with`] (decode) or
//! [`Model::prefill_chunk_batch`] (prefill) over them — layer-major, so
//! each block's weights are streamed once per sweep for the whole batch —
//! and returns per-request results in the same order.
//!
//! Parallelism: the batch is split into contiguous chunks, one scoped worker
//! thread per chunk (`std::thread::scope`; the offline vendor set has no
//! rayon, and scoped threads give the same fixed-order reduction a rayon
//! pool would). Each worker owns a [`DecodeBufs`] so the per-layer inner
//! loop is allocation-free (per sweep there remain O(batch) small setup
//! allocations: hidden-state and logits vectors), and results are
//! stitched back together in chunk order —
//! a fixed-order reduction. Every request's forward touches only its own
//! cache and hidden state, so the parallel step is **bit-identical** to the
//! sequential one; the engine's golden test pins this.
//!
//! GEAR component timings accumulate in worker-thread thread-locals; the
//! executor drains them and folds them back into the engine thread's
//! accumulator so the Fig 3a breakdown still covers off-thread work.

use crate::model::transformer::{DecodeBufs, DecodeSlot, PrefillSlot};
use crate::model::Model;
use crate::util::timing::PhaseTimer;

use super::scheduler::ActiveRequest;

/// How the engine executes a decode sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Whole batch on the engine thread (the reference semantics).
    Sequential,
    /// Batch chunked across scoped worker threads.
    Batched,
}

/// Executes batched decode steps for the engine.
pub struct BatchExecutor {
    mode: ExecMode,
    /// Worker-thread cap (host parallelism for `Batched`, 1 for
    /// `Sequential`).
    workers: usize,
    /// Engine-thread scratch, used for inline (unthreaded) execution.
    bufs: DecodeBufs,
}

/// Batches smaller than this run inline (still layer-major, just
/// unthreaded): per-sweep thread spawn plus per-worker scratch setup costs
/// tens of microseconds, which dominates small-model decode steps. 8 is
/// where the parallel win is promised and measured (`bench_throughput
/// -- --compare`); below it the inline path is never slower than the old
/// per-request loop.
const MIN_FANOUT: usize = 8;

/// Prefill chunks thread at a much lower fan-in than decode steps: one
/// chunk is O(chunk × prompt-so-far) attention work per layer, hundreds of
/// times a decode step, so the per-sweep spawn cost amortizes already at
/// two concurrent prefills.
const MIN_PREFILL_FANOUT: usize = 2;

impl BatchExecutor {
    pub fn new(model: &Model, mode: ExecMode) -> BatchExecutor {
        let workers = match mode {
            ExecMode::Sequential => 1,
            ExecMode::Batched => {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }
        };
        BatchExecutor { mode, workers, bufs: DecodeBufs::new(model.config()) }
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Advance every request in `batch` one decode step; logits come back
    /// in `batch` order regardless of which worker produced them.
    pub fn run(&mut self, model: &Model, batch: &mut [&mut ActiveRequest]) -> Vec<Vec<f32>> {
        let b = batch.len();
        if b == 0 {
            return Vec::new();
        }
        let workers = self.workers.min(b);
        if workers <= 1 || b < MIN_FANOUT {
            let mut slots: Vec<DecodeSlot> = batch
                .iter_mut()
                .map(|a| DecodeSlot { token: a.next_token, pos: a.pos, cache: &mut a.cache })
                .collect();
            return model.decode_batch_with(&mut slots, &mut self.bufs);
        }

        let chunk = b.div_ceil(workers);
        let n_chunks = b.div_ceil(chunk);
        let mut partials: Vec<(Vec<Vec<f32>>, PhaseTimer)> =
            (0..n_chunks).map(|_| (Vec::new(), PhaseTimer::new())).collect();
        std::thread::scope(|s| {
            for (reqs, out) in batch.chunks_mut(chunk).zip(partials.iter_mut()) {
                s.spawn(move || {
                    let mut bufs = DecodeBufs::new(model.config());
                    let mut slots: Vec<DecodeSlot> = reqs
                        .iter_mut()
                        .map(|a| DecodeSlot {
                            token: a.next_token,
                            pos: a.pos,
                            cache: &mut a.cache,
                        })
                        .collect();
                    let logits = model.decode_batch_with(&mut slots, &mut bufs);
                    *out = (logits, crate::gear::take_phase_timings());
                });
            }
        });

        // Fixed-order reduction: chunk order == batch order.
        let mut logits = Vec::with_capacity(b);
        for (part, phases) in partials {
            logits.extend(part);
            crate::gear::merge_phase_timings(&phases);
        }
        debug_assert_eq!(logits.len(), b);
        logits
    }

    /// Advance every slot's prefill by one chunk. Results land in each
    /// slot's [`crate::model::PrefillState`], so there is nothing to
    /// reduce; slots are split across scoped workers exactly like decode
    /// chunks. Every slot's chunk touches only its own state, so the
    /// threaded round is bit-identical to the inline one. (No GEAR
    /// component work happens here — compression runs at commit time on the
    /// engine thread — so no timing fold-back is needed.)
    pub fn run_prefill(&mut self, model: &Model, slots: &mut [PrefillSlot<'_>]) {
        let b = slots.len();
        if b == 0 {
            return;
        }
        let workers = self.workers.min(b);
        if workers <= 1 || b < MIN_PREFILL_FANOUT {
            model.prefill_chunk_batch(slots, &mut self.bufs);
            return;
        }
        let chunk = b.div_ceil(workers);
        std::thread::scope(|s| {
            for part in slots.chunks_mut(chunk) {
                s.spawn(move || {
                    let mut bufs = DecodeBufs::new(model.config());
                    model.prefill_chunk_batch(part, &mut bufs);
                });
            }
        });
    }
}
