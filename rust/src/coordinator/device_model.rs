//! Analytic GPU step-time model for the throughput experiments.
//!
//! This testbed is a single CPU core, so raw wall-clock cannot reproduce the
//! paper's Fig 3c (throughput vs batch on a V100): on a GPU, decoding is
//! *memory-bandwidth bound* — a decode step streams the model weights once
//! for the whole batch plus each request's KV cache, so larger batches
//! amortize the weight reads. We therefore reproduce Fig 3b (memory) from
//! *real* byte accounting and Fig 3c from this calibrated bandwidth model:
//!
//! ```text
//! step_time(B) = (W + Σ_b kv_bytes(b)) / BW  +  B · t_overhead(method)
//! ```
//!
//! where `W` is weight bytes, `kv_bytes` comes from the engine's exact cache
//! accounting, `BW` is device bandwidth, and `t_overhead` is the per-token
//! cost of the method's extra compute (dequant, low-rank forward, sparse),
//! calibrated as a bytes-equivalent from the component FLOP counts. CPU
//! wall-clock numbers are reported alongside as the honest local measurement
//! (EXPERIMENTS.md discusses both).

/// Device parameters. Defaults approximate an NVIDIA V100-16GB (the paper's
/// testbed): 900 GB/s HBM2, 16 GB capacity.
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    /// HBM bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Usable memory in bytes.
    pub capacity: usize,
    /// Fraction of peak bandwidth achieved by decode kernels.
    pub efficiency: f64,
}

impl DeviceModel {
    pub fn v100() -> DeviceModel {
        DeviceModel { bandwidth: 900e9, capacity: 16 << 30, efficiency: 0.6 }
    }

    /// RTX Titan (Fig 5): 672 GB/s, 24 GB.
    pub fn rtx_titan() -> DeviceModel {
        DeviceModel { bandwidth: 672e9, capacity: 24 << 30, efficiency: 0.6 }
    }

    /// Seconds for one decode sweep of a batch.
    ///
    /// * `weight_bytes` — model weights streamed once per step.
    /// * `kv_bytes` — per-request cache bytes actually resident (already
    ///   compressed for GEAR; this is where compression pays off).
    /// * `overhead_bytes` — extra traffic/compute of the compression method
    ///   expressed in byte-equivalents (scales/zeros re-reads, low-rank
    ///   factors, sparse values), per request.
    pub fn step_seconds(
        &self,
        weight_bytes: usize,
        kv_bytes: &[usize],
        overhead_bytes: &[usize],
    ) -> f64 {
        let moved: usize =
            weight_bytes + kv_bytes.iter().sum::<usize>() + overhead_bytes.iter().sum::<usize>();
        moved as f64 / (self.bandwidth * self.efficiency)
    }

    /// Tokens/second for a steady-state batch where every request moves
    /// `kv_per_req` cache bytes per step.
    pub fn throughput(
        &self,
        batch: usize,
        weight_bytes: usize,
        kv_per_req: usize,
        overhead_per_req: usize,
    ) -> f64 {
        let kv = vec![kv_per_req; batch];
        let ov = vec![overhead_per_req; batch];
        batch as f64 / self.step_seconds(weight_bytes, &kv, &ov)
    }

    /// Max batch size fitting `capacity` given weights and per-request cache.
    pub fn max_batch(&self, weight_bytes: usize, kv_per_req: usize) -> usize {
        if kv_per_req == 0 {
            return usize::MAX;
        }
        self.capacity.saturating_sub(weight_bytes) / kv_per_req
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_batch_higher_throughput() {
        let d = DeviceModel::v100();
        let w = 7usize << 30; // 7 GB of weights (8-bit 7B model)
        let kv = 100 << 20;
        let t1 = d.throughput(1, w, kv, 0);
        let t8 = d.throughput(8, w, kv, 0);
        assert!(t8 > t1 * 3.0, "t1={t1} t8={t8}");
    }

    #[test]
    fn smaller_kv_higher_throughput_at_same_batch() {
        let d = DeviceModel::v100();
        let w = 7usize << 30;
        let t_fp16 = d.throughput(8, w, 400 << 20, 0);
        let t_gear = d.throughput(8, w, 100 << 20, 10 << 20);
        assert!(t_gear > t_fp16);
    }

    #[test]
    fn max_batch_scales_inversely_with_kv() {
        let d = DeviceModel::v100();
        let w = 7usize << 30;
        let fp16 = d.max_batch(w, 3 << 30);
        let gear = d.max_batch(w, (3 << 30) / 4);
        assert_eq!(fp16, 3);
        assert_eq!(gear, 12);
    }

    #[test]
    fn step_time_linear_in_bytes() {
        let d = DeviceModel::v100();
        let a = d.step_seconds(1 << 30, &[1 << 20], &[0]);
        let b = d.step_seconds(2 << 30, &[2 << 20], &[0]);
        assert!((b / a - 2.0).abs() < 0.01);
    }
}
