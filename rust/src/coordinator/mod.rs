//! The serving coordinator (layer 3).
//!
//! A vLLM-style engine specialized for GEAR-compressed KV caches:
//!
//! * [`request`] — generation requests, results, lifecycle states.
//! * [`engine`] — continuous-batching prefill/decode loop over a byte-
//!   budgeted cache pool, with preemption when memory runs out.
//! * [`metrics`] — latency/throughput counters + the GEAR component time
//!   breakdown (Fig 3a).
//! * [`device_model`] — analytic V100-class step-time model used by the
//!   throughput benches (this testbed is a single CPU core; see DESIGN.md
//!   §3 on why byte accounting + a bandwidth model reproduces Fig 3b/3c).
//! * [`server`] — a minimal TCP line-protocol front-end.

pub mod device_model;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod server;

pub use engine::{Engine, EngineConfig};
pub use request::{GenRequest, GenResult, RequestId};
