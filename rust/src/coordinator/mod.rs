//! The serving coordinator (layer 3): a two-plane engine for GEAR-compressed
//! KV caches.
//!
//! The engine is split into a **scheduling plane** (policy) and an
//! **execution plane** (model math), composed by [`engine::Engine`]:
//!
//! * [`scheduler`] — the policy half: FCFS admission against a byte budget,
//!   recompute preemption of the youngest request, finish bookkeeping.
//!   Deterministic and sequential by construction.
//! * [`executor`] — the execution half: one layer-major batched decode step
//!   for the whole active set per sweep, chunked across scoped worker
//!   threads with a fixed-order reduction. Bit-identical to sequential
//!   execution; [`executor::ExecMode`] selects between them.
//! * [`engine`] — the composition: emit → execute → commit sweeps over a
//!   byte-budgeted cache pool.
//! * [`request`] — generation requests, results, lifecycle states.
//! * [`metrics`] — latency/throughput counters + the GEAR component time
//!   breakdown (Fig 3a), including work done on executor workers.
//! * [`device_model`] — analytic V100-class step-time model used by the
//!   throughput benches (see DESIGN.md §3 on why byte accounting + a
//!   bandwidth model reproduces Fig 3b/3c).
//! * [`server`] — a minimal TCP line-protocol front-end.
//!
//! Later PRs extend the execution plane without touching policy: prefill
//! chunking slots in as a second executor entry point, and shard-per-layer
//! execution replaces the chunk split inside [`executor::BatchExecutor`].

pub mod device_model;
pub mod engine;
pub mod executor;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use engine::{Engine, EngineConfig};
pub use executor::ExecMode;
pub use request::{GenRequest, GenResult, RequestId};
