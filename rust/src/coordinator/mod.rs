//! The serving coordinator (layer 3): a two-plane engine for GEAR-compressed
//! KV caches.
//!
//! The engine is split into a **scheduling plane** (policy) and an
//! **execution plane** (model math), composed by [`engine::Engine`]:
//!
//! * [`scheduler`] — the policy half: FCFS admission against a byte budget,
//!   recompute preemption of the youngest request, finish bookkeeping.
//!   Admission is immediate — prompts are *not* prefilled inline; a request
//!   enters the active set in `ReqPhase::Prefill` and its prompt is
//!   processed in fixed-size chunks across sweeps. Deterministic and
//!   sequential by construction.
//! * [`executor`] — the execution half, built on a **persistent worker
//!   pool** spawned once per engine (`GEAR_POOL_THREADS`, default host
//!   parallelism); workers park on a condvar between sweeps and pin their
//!   scratch (`DecodeBufs`, attention + per-segment kernel buffers, pooled
//!   hidden states) for their lifetime. Three entry points per sweep: one
//!   layer-major batched round of prefill chunks
//!   ([`executor::BatchExecutor::run_prefill`]), one layer-major batched
//!   decode step ([`executor::BatchExecutor::run_into`]) for the whole
//!   active set — each dispatched as contiguous chunk descriptors with a
//!   fixed-order reduction — plus an asynchronous flush lane: sealed
//!   segment-compression jobs submitted at commit
//!   ([`executor::BatchExecutor::submit_flush`]) run on idle workers and
//!   are joined one sweep later ([`executor::BatchExecutor::join_flush`]).
//!   A third plane, [`executor::ExecMode::Pipelined`], shards the *layers*
//!   instead of the batch: contiguous layer ranges become pipeline stages
//!   (`GEAR_PIPELINE_STAGES`, default one per worker), each request's
//!   hidden state streams stage-to-stage through a counter-guarded
//!   hand-off, and stage `s` runs request `i` while stage `s+1` runs
//!   request `i−1` — so decode parallelizes even at batch = 1, and each
//!   stage services flush jobs for its own layers (cache locality the
//!   batch split can't offer). Bit-identical to sequential execution for
//!   every pool size and stage count; [`executor::ExecMode`] selects
//!   between them.
//! * [`engine`] — the composition: **emit → reserve → prefill chunks →
//!   decode batch → join/submit flushes → commit** sweeps over a
//!   byte-budgeted cache pool. The reserve phase pre-books each request's
//!   worst-case byte growth for the sweep (exact per-method step bounds
//!   from `gear::size`, plus the in-flight chunk bytes of active
//!   prefills), so real cache bytes never overshoot the budget mid-sweep.
//!   Decode appends only *seal* full streaming buffers; at commit the
//!   engine joins the flushes it submitted one sweep earlier (the first
//!   point byte accounting observes their results), then detaches and
//!   submits every newly sealed (request, layer) pair — those jobs
//!   compress concurrently with the *next* sweep's prefill and decode
//!   rounds, with reservations, peak bytes, and token streams unchanged.
//!   The commit phase folds unused headroom back.
//! * [`request`] — generation requests, results, lifecycle states.
//! * [`metrics`] — latency/throughput counters + the GEAR component time
//!   breakdown (Fig 3a), including work done on executor workers; carries
//!   the [`crate::trace::TraceSummary`] when tracing is on and renders
//!   the plain-text snapshot behind the server's `metrics` verb.
//! * [`device_model`] — analytic V100-class step-time model used by the
//!   throughput benches (see DESIGN.md §3 on why byte accounting + a
//!   bandwidth model reproduces Fig 3b/3c).
//! * [`server`] — a minimal TCP line-protocol front-end.
//!
//! The full concurrency contract — which phase may observe which cache
//! state, and why the schedule is bit-identical across exec modes, pool
//! sizes, and pipeline stage counts — is documented in
//! `docs/ARCHITECTURE.md`. The execution plane has grown without ever
//! touching policy: PR 1 cut the executor seam, PR 3 made the pool
//! persistent, PR 4 detached the flush lane, PR 5 added the layer-sharded
//! pipeline plane behind the same `run_into` entry point, PR 6 threaded
//! the structured trace plane (`crate::trace`) through every commit point
//! — per-thread event rings folded at the deterministic joins, so the
//! logical event stream is itself bit-identical across planes — and this
//! PR added [`executor::ExecMode::Hybrid`]: the scheduler's
//! [`scheduler::PlanePolicy`] picks the batch-chunked or pipelined plane
//! per sweep from the decode batch size (threshold + hysteresis), both
//! planes sharing one warm pool and one flush lane, so every switch
//! sequence stays bit-identical too (`tests/hybrid_golden.rs`).

pub mod device_model;
pub mod engine;
pub mod executor;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use engine::{Engine, EngineConfig};
pub use executor::{ExecMode, Plane};
pub use request::{GenRequest, GenResult, RequestId};
