//! Generation request/response types.

use crate::model::sampler::Sampler;

pub type RequestId = u64;

/// A generation request submitted to the engine.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: RequestId,
    /// Prompt token ids (tokenized by the caller; BOS already applied).
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Stop generation at any of these token ids (EOS, '\n', …).
    pub stop_tokens: Vec<u32>,
}

impl GenRequest {
    pub fn greedy(id: RequestId, prompt: Vec<u32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            sampler: Sampler::Greedy,
            stop_tokens: vec![crate::model::config::EOS],
        }
    }

    /// Also stop on newline (the task formats end answers with '\n').
    pub fn with_newline_stop(mut self) -> GenRequest {
        let t = crate::model::config::Tokenizer::new();
        self.stop_tokens.push(t.encode("\n")[0]);
        self
    }
}

/// Why a request finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Stop,
    Length,
    /// Rejected: can never fit in the memory budget even alone.
    OutOfMemory,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: RequestId,
    /// Generated token ids (stop token excluded).
    pub output: Vec<u32>,
    pub finish: FinishReason,
    /// Tokens in the prompt.
    pub prompt_len: usize,
    /// Times the request was preempted and re-prefilled.
    pub preemptions: usize,
    /// Wall-clock seconds spent queued before first prefill.
    pub queue_secs: f64,
    /// Wall-clock seconds from first prefill to finish.
    pub run_secs: f64,
}

impl GenResult {
    pub fn text(&self) -> String {
        crate::model::config::Tokenizer::new().decode(&self.output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_request_defaults() {
        let r = GenRequest::greedy(1, vec![1, 2, 3], 16);
        assert_eq!(r.sampler, Sampler::Greedy);
        assert_eq!(r.stop_tokens, vec![crate::model::config::EOS]);
        let r = r.with_newline_stop();
        assert_eq!(r.stop_tokens.len(), 2);
    }
}
