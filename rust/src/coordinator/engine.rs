//! The serving engine: a two-plane architecture over a byte-budgeted cache
//! pool.
//!
//! * **Scheduling plane** ([`super::scheduler`]) — admission, budget
//!   accounting, preemption, finish bookkeeping. Pure policy, FCFS
//!   deterministic.
//! * **Execution plane** ([`super::executor`]) — one decode step for the
//!   *whole* active set, one round of prefill chunks, and the deferred
//!   segment flushes the decode step seals, each dispatched as contiguous
//!   chunk descriptors across a persistent worker pool.
//!
//! A sweep runs **emit → reserve → prefill chunks → decode batch →
//! join/submit flushes → commit** (`docs/ARCHITECTURE.md` draws the full
//! picture, including which phase may observe which cache state):
//! 1. **Emit** (policy, sequential): each decoding request's previously
//!    sampled token is emitted; stop/length/context finishes retire.
//! 2. **Reserve** (policy, sequential, fixed order): per request, the
//!    sweep's worst-case byte growth is reserved *before* any model math —
//!    `cache.step_growth_bound()` for decoders (exact per-method flush
//!    accounting from `gear::size`, covering both a pending seal and the
//!    pending install of a flush submitted last sweep), the next chunk's
//!    FP16-accounted in-flight KV for prefillers. On exhaustion the
//!    youngest request is preempted (recompute preemption) and the
//!    reservation retries, so real cache bytes can no longer overshoot the
//!    budget mid-sweep. Reserve never waits on a flush: the bound accounts
//!    for in-flight jobs without observing their results.
//! 3. **Prefill** (execute): every request still in
//!    [`super::scheduler::ReqPhase::Prefill`] advances one chunk
//!    (`prefill_chunk` tokens) in a single [`BatchExecutor::run_prefill`]
//!    call — concurrently, on the same pool, with any flush jobs submitted
//!    at the previous sweep's commit (the overlap this engine is after). A
//!    request whose final chunk completed commits: the whole prompt's
//!    exact K/V compresses through the one-shot `ingest_prefill` path
//!    (bit-identical to whole-prompt prefill), its first token is sampled,
//!    and it joins the decode set *next* sweep.
//! 4. **Decode** (execute): the surviving decoders advance one token in a
//!    single [`BatchExecutor::run_into`] call, writing into the engine's
//!    pooled logits vectors. Attention reads any still-detached buffer
//!    rows as dense FP16 — their content is timing-independent — and
//!    streaming buffers the step fills are *sealed*, not compressed inline
//!    ([`crate::kvcache::LayerKv::append_deferred`]).
//! 5. **Join + submit** (the split flush commit point, fixed
//!    request-serial × layer order): flush jobs submitted at these
//!    requests' *previous* commit are joined — still-queued work is stolen
//!    inline, finished work just installs — because byte accounting below
//!    is the first observer of their results. Then every buffer this
//!    step sealed is detached ([`crate::kvcache::LayerKv::detach_flush`])
//!    and submitted to the pool without blocking; those jobs overlap the
//!    *next* sweep and join one commit from now.
//! 6. **Commit** (policy, sequential, fixed order): per request — sample
//!    the next token and fold the sweep's transient headroom back into the
//!    steady reservation (with a preempt-and-retry backstop should a cache
//!    ever outgrow its bound).
//!
//! ## Determinism contract
//!
//! Policy phases are sequential and order-fixed; the execute phases are
//! bit-identical across [`ExecMode::Sequential`], [`ExecMode::Batched`]
//! (each request's forward touches only its own state, reductions are
//! fixed-order), and [`ExecMode::Pipelined`] (stage boundaries only
//! partition each request's per-layer loop; the hand-off order is fixed by
//! batch position); and the flush join points are fixed by *data
//! dependence* — the sealed request's next commit — never by job
//! completion timing. `Sequential` follows the identical submit/join
//! protocol (the join steals and runs the job inline), so every mode
//! observes identical cache state at every observation point: the three
//! planes produce identical token streams, finish reasons, preemption
//! schedules, and peak cache bytes for every pool size and stage count —
//! `tests/batched_vs_sequential.rs` and `tests/pool_golden.rs` pin this,
//! including a flush held in flight across a preemption of its own request
//! and preemption mid-pipeline. [`ExecMode::Hybrid`] selects one of the
//! two pooled planes per sweep (the scheduler's
//! [`super::scheduler::PlanePolicy`], reading only the deterministic
//! decode-batch sequence), so it inherits the same guarantee for every
//! switch sequence — `tests/hybrid_golden.rs` pins it property-style,
//! switches with flushes outstanding included. Chunked prefill is
//! likewise bit-identical to whole-prompt prefill for every chunk size
//! (`tests/prefill_chunked.rs`).
//!
//! Budget semantics: `peak_cache_bytes` tracks reservations, which *lead*
//! real bytes (phase 2) instead of trailing them. Byte accounting observes
//! caches only at commit points (settle), where outstanding flushes have
//! just been joined; detached-but-unjoined rows are counted at their
//! still-resident FP16 size, and the job's private snapshot (one sealed
//! buffer per in-flight (request, layer)) is the only transient the budget
//! does not see.

use std::path::PathBuf;
use std::time::Instant;

use crate::kvcache::CacheSpec;
use crate::model::{Model, PrefillSlot};
use crate::trace::{self, EventKind, FinishClass, Quality, SweepPhase, Tracer};

use super::executor::{BatchExecutor, ExecMode, FlushJoined, Plane};
use super::metrics::EngineMetrics;
use super::request::{FinishReason, GenRequest, GenResult};
use super::scheduler::{ActiveRequest, ReqPhase, Scheduler};

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub spec: CacheSpec,
    /// Max simultaneously-active requests.
    pub max_batch: usize,
    /// KV-cache byte budget (the "GPU memory" left after weights).
    pub budget_bytes: usize,
    /// Seed for sampling RNGs.
    pub seed: u64,
    /// How decode sweeps execute. `Batched` is the default; `Sequential`
    /// is the single-thread reference with identical results.
    pub exec: ExecMode,
    /// Prefill token budget per request per sweep: long prompts are
    /// prefilled `prefill_chunk` tokens at a time, interleaved with decode
    /// sweeps, so an arriving long prompt never stalls the active batch.
    /// The token stream is bit-identical for every value.
    pub prefill_chunk: usize,
    /// Worker-pool size for the pooled exec modes. `None` (the default)
    /// resolves through [`super::executor::default_pool_threads`]
    /// (`GEAR_POOL_THREADS`, else host parallelism). The token stream is
    /// bit-identical for every value (`tests/pool_golden.rs`).
    pub pool_threads: Option<usize>,
    /// Stage count for [`ExecMode::Pipelined`]: the model's layers are
    /// partitioned into this many contiguous pipeline stages (clamped to
    /// the layer count). `None` (the default) resolves through
    /// [`super::executor::default_pipeline_stages`]
    /// (`GEAR_PIPELINE_STAGES`, else one stage per pool worker). The token
    /// stream is bit-identical for every value (`tests/pool_golden.rs`).
    pub pipeline_stages: Option<usize>,
    /// Decode-batch threshold for [`ExecMode::Hybrid`]'s per-sweep plane
    /// policy: sweeps at or above it dispatch batch-chunked, smaller
    /// sweeps pipeline (with hysteresis — see
    /// [`super::scheduler::PlanePolicy`]). `None` (the default) resolves
    /// through [`super::executor::default_hybrid_threshold`]
    /// (`GEAR_HYBRID_THRESHOLD`, else `MIN_FANOUT`). The token stream is
    /// bit-identical for every value (`tests/hybrid_golden.rs`).
    pub hybrid_threshold: Option<usize>,
    /// Trace export path: [`Tracer::export_files`] writes Perfetto JSON
    /// here and the JSONL journal next to it after every
    /// [`Engine::run_to_completion`]. `None` falls back to the
    /// `GEAR_TRACE` environment variable at engine construction; tracing
    /// stays fully disabled (no rings, no locks, one relaxed atomic load
    /// on shared paths) when neither is set and `trace_capture` is off.
    pub trace: Option<PathBuf>,
    /// Capture trace events in memory without exporting files — the
    /// golden tests read the logical stream via [`Engine::tracer`].
    pub trace_capture: bool,
}

impl EngineConfig {
    pub fn new(spec: CacheSpec) -> EngineConfig {
        EngineConfig {
            spec,
            max_batch: 64,
            budget_bytes: usize::MAX,
            seed: 0x5EED,
            exec: ExecMode::Batched,
            prefill_chunk: 128,
            pool_threads: None,
            pipeline_stages: None,
            hybrid_threshold: None,
            trace: None,
            trace_capture: false,
        }
    }

    pub fn with_budget(mut self, bytes: usize) -> Self {
        self.budget_bytes = bytes;
        self
    }

    pub fn with_max_batch(mut self, b: usize) -> Self {
        self.max_batch = b;
        self
    }

    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    pub fn with_prefill_chunk(mut self, tokens: usize) -> Self {
        self.prefill_chunk = tokens.max(1);
        self
    }

    pub fn with_pool_threads(mut self, threads: usize) -> Self {
        self.pool_threads = Some(threads.max(1));
        self
    }

    /// Pin the [`ExecMode::Pipelined`] stage count (see
    /// [`Self::pipeline_stages`]).
    pub fn with_pipeline_stages(mut self, stages: usize) -> Self {
        self.pipeline_stages = Some(stages.max(1));
        self
    }

    /// Pin the [`ExecMode::Hybrid`] plane-switch threshold (see
    /// [`Self::hybrid_threshold`]; clamped to at least 1).
    pub fn with_hybrid_threshold(mut self, threshold: usize) -> Self {
        self.hybrid_threshold = Some(threshold.max(1));
        self
    }

    /// Enable tracing and export the run to `path` (Perfetto JSON; the
    /// JSONL journal lands next to it with a `.jsonl` extension).
    /// Equivalent to launching with `GEAR_TRACE=path`.
    pub fn with_trace(mut self, path: impl Into<PathBuf>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Enable in-memory trace capture without file export (see
    /// [`Self::trace_capture`]).
    pub fn with_trace_capture(mut self) -> Self {
        self.trace_capture = true;
        self
    }
}

/// Synchronous serving engine: scheduler (policy) + batch executor
/// (execution) around one model.
pub struct Engine {
    model: Model,
    scheduler: Scheduler,
    executor: BatchExecutor,
    active: Vec<ActiveRequest>,
    finished: Vec<GenResult>,
    /// Pooled per-request logits vectors, reused across decode sweeps so a
    /// steady sweep performs no O(batch) allocation.
    logits_buf: Vec<Vec<f32>>,
    /// The engine thread's trace collector; `None` leaves tracing fully
    /// disabled (see [`crate::trace`] for the cost contract).
    tracer: Option<Tracer>,
    pub metrics: EngineMetrics,
}

impl Engine {
    pub fn new(model: Model, cfg: EngineConfig) -> Engine {
        let trace_path = cfg.trace.clone().or_else(|| {
            std::env::var_os("GEAR_TRACE").filter(|s| !s.is_empty()).map(PathBuf::from)
        });
        let tracer =
            (cfg.trace_capture || trace_path.is_some()).then(|| Tracer::new(trace_path));
        let mut executor =
            BatchExecutor::new(&model, cfg.exec, cfg.pool_threads, cfg.pipeline_stages);
        executor.set_trace(tracer.is_some());
        Engine {
            scheduler: Scheduler::new(cfg),
            executor,
            model,
            active: Vec::new(),
            finished: Vec::new(),
            logits_buf: Vec::new(),
            tracer,
            metrics: EngineMetrics::default(),
        }
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The engine's trace collector, when tracing is enabled
    /// (`GEAR_TRACE`, [`EngineConfig::with_trace`], or
    /// [`EngineConfig::with_trace_capture`]). The golden tests read the
    /// deterministic logical stream through [`Tracer::logical`].
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    pub fn submit(&mut self, req: GenRequest) {
        if let Some(t) = &mut self.tracer {
            t.emit(EventKind::Enqueue { req_id: req.id });
        }
        self.scheduler.submit(req);
    }

    /// Run one engine sweep over all active requests (emit → reserve →
    /// prefill chunks → decode batch → flush → commit). Returns the number
    /// of tokens generated this step.
    fn sweep(&mut self) -> usize {
        // Phase 1 — emit previously sampled tokens; retire finishes. The
        // sampled token from the previous step/prefill is emitted first;
        // stop tokens never enter the output. Requests still prefilling
        // have no sampled token yet and are skipped.
        let max_seq = self.model.config().max_seq;
        let mut produced = 0;
        let mut idx = 0;
        while idx < self.active.len() {
            if matches!(self.active[idx].phase, ReqPhase::Prefill(_)) {
                idx += 1;
                continue;
            }
            let stopped = {
                let a = &self.active[idx];
                a.req.stop_tokens.contains(&a.next_token)
            };
            if stopped {
                self.finish_at(idx, FinishReason::Stop);
                continue;
            }
            let done = {
                let a = &mut self.active[idx];
                a.output.push(a.next_token);
                a.output.len() >= a.req.max_new_tokens || a.pos + 1 >= max_seq
            };
            produced += 1;
            self.metrics.generated_tokens += 1;
            if done {
                self.finish_at(idx, FinishReason::Length);
                continue;
            }
            idx += 1;
        }
        if self.active.is_empty() {
            return produced;
        }

        // Phase 2 — pre-reserve this sweep's worst-case byte growth.
        let t_reserve = self.span_start();
        self.reserve_phase();
        self.end_span(SweepPhase::Reserve, t_reserve);

        // Snapshot who decodes this sweep: requests whose prefill commits
        // in phase 3 join the decode set next sweep (their first token must
        // be emitted before their first decode step).
        let decode_serials: Vec<u64> = self
            .active
            .iter()
            .filter(|a| matches!(a.phase, ReqPhase::Decode))
            .map(|a| a.serial)
            .collect();

        // Phase 3 — one round of prefill chunks.
        let t_prefill = self.span_start();
        self.prefill_phase();
        self.end_span(SweepPhase::Prefill, t_prefill);

        // Phase 4–6 — batched decode + flush commit point + commit.
        self.decode_phase(&decode_serials);
        produced
    }

    /// Start timestamp for an engine-thread [`EventKind::Phase`] span;
    /// `None` (and therefore free) when tracing is off.
    fn span_start(&self) -> Option<u64> {
        self.tracer.as_ref().map(|_| trace::now_ns())
    }

    /// Close a phase span opened by [`Self::span_start`].
    fn end_span(&mut self, phase: SweepPhase, start: Option<u64>) {
        if let (Some(t), Some(s)) = (&mut self.tracer, start) {
            t.emit_span(EventKind::Phase { phase }, s);
        }
    }

    /// Reserve, per active request and *before* any model math, the bytes
    /// this sweep can grow its cache by: the exact one-step growth bound
    /// for decoders, the FP16-accounted in-flight KV through the next chunk
    /// for prefillers. Preempts the youngest request (recompute preemption)
    /// when the budget cannot cover a reservation.
    fn reserve_phase(&mut self) {
        let chunk = self.scheduler.cfg().prefill_chunk.max(1);
        let serials: Vec<u64> = self.active.iter().map(|a| a.serial).collect();
        for serial in serials {
            loop {
                let Some(i) = self.active.iter().position(|a| a.serial == serial) else { break };
                let a = &self.active[i];
                let need = match &a.phase {
                    ReqPhase::Decode => a.cache.nbytes() + a.cache.step_growth_bound(),
                    ReqPhase::Prefill(state) => {
                        let next_done = (state.done() + chunk).min(state.total());
                        state.transient_fp16_bytes(next_done)
                    }
                };
                let held = a.reserved + a.headroom;
                if need <= held {
                    if let Some(t) = &mut self.tracer {
                        t.emit(EventKind::Reserve { serial, bytes: need as u64 });
                    }
                    break;
                }
                if self.scheduler.budget.try_reserve(need - held) {
                    self.active[i].headroom += need - held;
                    if let Some(t) = &mut self.tracer {
                        t.emit(EventKind::Reserve { serial, bytes: need as u64 });
                    }
                    break;
                }
                // Budget exhausted: preempt the youngest and retry. Each
                // preemption shrinks the active set, so this terminates —
                // in the worst case the reserving request itself is
                // preempted (or OOM-finished when it is the last one).
                self.scheduler.preempt_youngest(
                    &mut self.active,
                    &mut self.finished,
                    &mut self.metrics,
                    &mut self.tracer,
                );
            }
        }
    }

    /// Advance every prefilling request by one chunk through the executor,
    /// then commit the requests whose prompt completed: compress the whole
    /// prompt into the cache (the same one-shot ingest as whole-prompt
    /// prefill — bit-identical layout and bytes), sample the first token,
    /// and settle the byte reservation.
    fn prefill_phase(&mut self) {
        let chunk = self.scheduler.cfg().prefill_chunk.max(1);
        let t0 = Instant::now();
        let mut completed: Vec<u64> = Vec::new();
        let n_chunks = {
            let mut slots: Vec<PrefillSlot> = Vec::new();
            for a in self.active.iter_mut() {
                let ActiveRequest { req, phase, serial, .. } = a;
                if let ReqPhase::Prefill(state) = phase {
                    let done = state.done();
                    let end = (done + chunk).min(req.prompt.len());
                    if end == req.prompt.len() {
                        completed.push(*serial);
                    }
                    if let Some(t) = &mut self.tracer {
                        t.emit(EventKind::PrefillChunk {
                            serial: *serial,
                            rows: (end - done) as u32,
                        });
                    }
                    slots.push(PrefillSlot { tokens: &req.prompt[done..end], state });
                }
            }
            if slots.is_empty() {
                return;
            }
            self.executor.run_prefill(&self.model, &mut slots);
            slots.len()
        };
        if let Some(t) = &mut self.tracer {
            t.fold(self.executor.take_trace_events());
        }
        self.metrics.prefill_chunks += n_chunks;

        for serial in completed {
            // A commit-time settle below can preempt other still-prefilling
            // requests; re-find each by serial and skip the evicted.
            let Some(i) = self.active.iter().position(|a| a.serial == serial) else { continue };
            let traced = self.tracer.is_some();
            if traced {
                // Scope the quality probe to this attributable compression;
                // anything already staged has lost its identity — count it
                // dropped rather than mislabel it.
                let stale = trace::take_staged_quality().len() as u64;
                if let Some(t) = &mut self.tracer {
                    t.note_quality_dropped(stale);
                }
                trace::set_quality_capture(true);
            }
            let a = &mut self.active[i];
            let phase = std::mem::replace(&mut a.phase, ReqPhase::Decode);
            let ReqPhase::Prefill(state) = phase else { unreachable!() };
            debug_assert!(state.is_complete());
            let last_logits = self.model.commit_prefill(state, &mut a.cache);
            if traced {
                trace::set_quality_capture(false);
            }
            a.next_token = a.req.sampler.sample(&last_logits, &mut a.rng);
            a.pos = a.req.prompt.len();
            self.metrics.prompt_tokens += a.pos;
            if traced {
                // `commit_prefill` compresses K then V per layer, layers in
                // order, so record 2l is layer l's Key and 2l+1 its Value.
                // Anything else (e.g. an FP16 cache stages nothing) is not
                // attributable — drop, never guess.
                let staged = trace::take_staged_quality();
                let n_layers = self.model.config().n_layers;
                if let Some(t) = &mut self.tracer {
                    if staged.len() == 2 * n_layers {
                        for (j, q) in staged.iter().enumerate() {
                            t.emit(EventKind::Quality(Quality::from_staged(
                                q,
                                serial,
                                (j / 2) as u32,
                                true,
                            )));
                        }
                    } else {
                        t.note_quality_dropped(staged.len() as u64);
                    }
                    t.emit(EventKind::FirstToken { serial });
                }
            }
            self.settle_reservation(serial);
        }
        self.metrics.prefill += t0.elapsed();
    }

    /// One batched decode step for the given (still-present) requests, then
    /// the split commit point — **join** the flushes these requests
    /// submitted a sweep ago, **submit** the seals this step produced —
    /// then the sequential fixed-order commit: sample the next token and
    /// settle the byte reservation. Requests are re-found by admission
    /// serial (caller-chosen `req.id`s need not be unique; serials are).
    fn decode_phase(&mut self, serials: &[u64]) {
        let t_step = Instant::now();
        let t_decode = self.span_start();
        let mut logits = std::mem::take(&mut self.logits_buf);
        // Plane chosen for this sweep under `ExecMode::Hybrid` (`None` in
        // the fixed modes); drives the per-plane metric split below.
        let mut chosen: Option<Plane> = None;
        let present: Vec<u64> = {
            let mut refs: Vec<&mut ActiveRequest> = self
                .active
                .iter_mut()
                .filter(|a| serials.contains(&a.serial))
                .collect();
            if refs.is_empty() {
                self.logits_buf = logits;
                return;
            }
            let present = refs.iter().map(|a| a.serial).collect();
            // Hybrid: consult the plane policy with this sweep's decode
            // batch size — a deterministic value (the contract) — and aim
            // the executor before dispatching. Part of the sequential
            // policy phase, so the chosen sequence is deterministic too.
            if self.executor.mode() == ExecMode::Hybrid {
                let plane = self.scheduler.plane_policy.choose(refs.len());
                self.executor.set_sweep_plane(plane);
                chosen = Some(plane);
                if let Some(t) = &mut self.tracer {
                    t.emit(EventKind::PlaneChosen {
                        batch: refs.len() as u32,
                        pipelined: plane == Plane::Pipelined,
                    });
                }
            }
            if let Some(t) = &mut self.tracer {
                t.emit(EventKind::DecodeStep { n_seqs: refs.len() as u32 });
            }
            self.executor.run_into(&self.model, &mut refs, &mut logits);
            present
        };
        if let Some(t) = &mut self.tracer {
            t.fold(self.executor.take_trace_events());
        }
        self.end_span(SweepPhase::Decode, t_decode);
        // Pipelined sweeps report per-stage busy/bubble; fold them into
        // the run totals (no-op for the other planes).
        self.metrics.record_stage_times(self.executor.stage_times());

        // Join half of the commit point: flush jobs submitted at these
        // requests' *previous* commit have overlapped a full sweep of
        // engine work (this sweep's prefill round and the decode step
        // above, which read the detached rows as dense buffer); now byte
        // accounting is about to observe the caches, so the compressed
        // segments must land. Joins run in fixed request-serial × layer
        // order and each job is a pure function of its sealed rows, so
        // pool size and timing cannot change bytes, peaks, or tokens.
        let t_flush = self.span_start();
        self.join_flushes(&present);

        // Submit half: detach every streaming buffer this decode step
        // sealed and queue its compression on the pool — without blocking.
        // The jobs run in the pool's idle gaps (strictly lower priority
        // than decode/prefill dispatches) and are joined at these
        // requests' next commit, right here, one sweep from now.
        self.submit_flushes(&present);
        self.end_span(SweepPhase::Flush, t_flush);

        for (lg, &serial) in logits.iter().zip(&present) {
            let Some(i) = self.active.iter().position(|a| a.serial == serial) else { continue };
            {
                let a = &mut self.active[i];
                a.pos += 1;
                a.next_token = a.req.sampler.sample(lg, &mut a.rng);
            }
            self.settle_reservation(serial);
        }
        self.logits_buf = logits;
        let step = t_step.elapsed();
        self.metrics.step_latencies.push(step);
        // Hybrid bookkeeping: attribute this sweep (and the tokens it
        // decoded) to the plane that ran it, so the bench can report
        // per-plane tok/s and the switch count.
        if let Some(plane) = chosen {
            match plane {
                Plane::Batched => {
                    self.metrics.hybrid_batched_sweeps += 1;
                    self.metrics.hybrid_batched_tokens += present.len();
                    self.metrics.hybrid_batched_time += step;
                }
                Plane::Pipelined => {
                    self.metrics.hybrid_pipelined_sweeps += 1;
                    self.metrics.hybrid_pipelined_tokens += present.len();
                    self.metrics.hybrid_pipelined_time += step;
                }
            }
            self.metrics.hybrid_switches = self.scheduler.plane_policy.switches();
        }
    }

    /// Join every outstanding flush of the given requests, in fixed
    /// request-serial × layer order, installing the compressed segments in
    /// place of the detached buffer rows. Still-queued jobs are stolen and
    /// run inline (always, in `ExecMode::Sequential` — making it the
    /// blocking baseline); finished jobs cost only the bookkeeping. Worker
    /// component timings fold back into the engine accumulator inside
    /// [`BatchExecutor::join_flush`], at this deterministic point.
    fn join_flushes(&mut self, present: &[u64]) {
        for &serial in present {
            let Some(i) = self.active.iter().position(|a| a.serial == serial) else { continue };
            if self.active[i].pending_flushes.is_empty() {
                continue;
            }
            let tickets = std::mem::take(&mut self.active[i].pending_flushes);
            for (layer_idx, ticket) in tickets {
                let FlushJoined { result, stalled, hidden, obs } =
                    self.executor.join_flush(ticket);
                self.active[i].cache.layers[layer_idx].install_flush(result);
                self.metrics.flush_stall += stalled;
                self.metrics.flush_overlap_won += hidden;
                if let Some(t) = &mut self.tracer {
                    t.emit(EventKind::FlushJoin { serial, layer: layer_idx as u32 });
                    if let Some(obs) = obs {
                        // The run span keeps its worker attribution; the
                        // quality records gain their (serial, layer)
                        // identity here, at the deterministic join — so
                        // the logical stream is mode-independent even
                        // though who compressed the segment is not.
                        t.note_quality_dropped(obs.stale);
                        t.fold(vec![obs.run]);
                        for q in &obs.quality {
                            t.emit(EventKind::Quality(Quality::from_staged(
                                q,
                                serial,
                                layer_idx as u32,
                                false,
                            )));
                        }
                    }
                }
            }
        }
    }

    /// Detach every sealed (request, layer) pair among the given requests —
    /// in fixed request-serial × layer order, the same order the matching
    /// joins will run — and submit the compression jobs to the executor.
    /// Joining before submitting (see [`Self::decode_phase`]) guarantees at
    /// most one job per layer is ever in flight.
    fn submit_flushes(&mut self, present: &[u64]) {
        for &serial in present {
            let Some(i) = self.active.iter().position(|a| a.serial == serial) else { continue };
            for layer_idx in 0..self.active[i].cache.layers.len() {
                let Some(work) = self.active[i].cache.layers[layer_idx].detach_flush() else {
                    continue;
                };
                if let Some(t) = &mut self.tracer {
                    let (layer, rows) = (layer_idx as u32, work.rows() as u32);
                    t.emit(EventKind::Seal { serial, layer, rows });
                    t.emit(EventKind::FlushSubmit { serial, layer, rows });
                }
                let ticket = self.executor.submit_flush(work, layer_idx);
                self.active[i].pending_flushes.push((layer_idx, ticket));
                self.metrics.flush_jobs += 1;
            }
        }
    }

    /// Fold a request's transient sweep headroom back into its steady
    /// reservation after its cache changed: keep `max(reserved, real)`,
    /// release the rest. If the cache outgrew even the pre-reserved bound
    /// (possible only if a `step_growth_bound` impl under-estimated), fall
    /// back to grow-with-preemption — the pre-chunked engine's commit path.
    fn settle_reservation(&mut self, serial: u64) {
        loop {
            let Some(i) = self.active.iter().position(|a| a.serial == serial) else { return };
            let a = &self.active[i];
            let real = a.cache.nbytes();
            let held = a.reserved + a.headroom;
            let steady = a.reserved.max(real);
            if steady <= held {
                if steady < held {
                    self.scheduler.budget.release(held - steady);
                }
                let a = &mut self.active[i];
                a.reserved = steady;
                a.headroom = 0;
                return;
            }
            if self.scheduler.budget.adjust(held, steady) {
                let a = &mut self.active[i];
                a.reserved = steady;
                a.headroom = 0;
                return;
            }
            self.scheduler.preempt_youngest(
                &mut self.active,
                &mut self.finished,
                &mut self.metrics,
                &mut self.tracer,
            );
        }
    }

    fn finish_at(&mut self, idx: usize, finish: FinishReason) {
        let a = self.active.swap_remove(idx);
        self.scheduler.budget.release(a.reserved + a.headroom);
        self.metrics.requests_finished += 1;
        if let Some(t) = &mut self.tracer {
            let reason = match finish {
                FinishReason::Stop => FinishClass::Stop,
                FinishReason::Length => FinishClass::Length,
                FinishReason::OutOfMemory => FinishClass::Oom,
            };
            t.emit(EventKind::Finish {
                serial: a.serial,
                reason,
                tokens: a.output.len() as u32,
            });
        }
        self.finished.push(a.into_result(finish));
    }

    /// Run one scheduling + execution step: admit what fits, then one
    /// sweep. Returns the number of tokens generated. Exposed so callers
    /// (and the interleaving tests) can observe per-sweep progress;
    /// [`Self::run_to_completion`] is a loop over this.
    pub fn step(&mut self) -> usize {
        self.scheduler.try_admit(
            &self.model,
            &mut self.active,
            &mut self.finished,
            &mut self.metrics,
            &mut self.tracer,
        );
        if self.active.is_empty() {
            return 0;
        }
        self.sweep()
    }

    /// Drive the engine until all submitted work is done; returns results
    /// in finish order.
    pub fn run_to_completion(&mut self) -> Vec<GenResult> {
        let t0 = Instant::now();
        // Reset component timers so the breakdown covers only this run.
        let _ = crate::gear::take_phase_timings();
        self.scheduler.budget.reset_peak();
        while self.pending() > 0 {
            // Progress is guaranteed: with nothing active, try_admit either
            // admits the head request or finishes it as OutOfMemory.
            self.step();
        }
        self.metrics.wall += t0.elapsed();
        self.metrics.peak_cache_bytes =
            self.metrics.peak_cache_bytes.max(self.scheduler.budget.peak());
        self.metrics.phases.merge(&crate::gear::take_phase_timings());
        // Fold the trace into the metrics and (re-)export. The tracer
        // accumulates across runs — enqueues can precede this call and a
        // server engine loops here — so each export is a cumulative
        // atomic rewrite, not an increment.
        if let Some(t) = &mut self.tracer {
            self.metrics.trace = Some(t.summary());
            if let Err(e) = t.export_files() {
                eprintln!("gear-serve: trace export failed: {e}");
            }
        }
        std::mem::take(&mut self.finished)
    }

    pub fn pending(&self) -> usize {
        self.scheduler.waiting_len() + self.active.len()
    }

    /// Active requests still in the chunked-prefill phase.
    pub fn active_prefilling(&self) -> usize {
        self.active.iter().filter(|a| matches!(a.phase, ReqPhase::Prefill(_))).count()
    }

    /// Bytes currently reserved against the cache budget (zero once all
    /// work has drained — the accounting invariant the tests pin).
    pub fn budget_used(&self) -> usize {
        self.scheduler.budget.used()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    fn tiny_engine(spec: CacheSpec, budget: usize) -> Engine {
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
        let model = Model::new(ModelWeights::random(cfg, 7));
        Engine::new(model, EngineConfig::new(spec).with_budget(budget))
    }

    #[test]
    fn serves_multiple_requests() {
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        for i in 0..5 {
            e.submit(GenRequest::greedy(i, vec![1, 2, 3, (i % 10) as u32 + 3], 8));
        }
        let results = e.run_to_completion();
        assert_eq!(results.len(), 5);
        for r in &results {
            assert!(matches!(r.finish, FinishReason::Stop | FinishReason::Length));
            assert!(r.output.len() <= 8);
        }
        assert_eq!(e.metrics.requests_finished, 5);
        assert!(e.metrics.generated_tokens > 0);
        assert!(e.metrics.max_concurrency >= 2);
    }

    #[test]
    fn identical_requests_identical_outputs() {
        // Determinism: same id -> same sampling path.
        let run = || {
            let mut e = tiny_engine(CacheSpec::gear(4), usize::MAX);
            e.submit(GenRequest::greedy(42, vec![1, 4, 6, 8], 10));
            e.run_to_completion().pop().unwrap().output
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn duplicate_request_ids_both_served() {
        // Caller-chosen ids need not be unique: the commit phase keys on
        // admission serials, so twin ids must not cross-contaminate state.
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        e.submit(GenRequest::greedy(7, vec![1, 2, 3], 6));
        e.submit(GenRequest::greedy(7, vec![1, 2, 3], 6));
        let results = e.run_to_completion();
        assert_eq!(results.len(), 2);
        // Same id + same prompt -> same sampler seed -> identical streams.
        assert_eq!(results[0].output, results[1].output);
        assert!(results.iter().all(|r| r.output.len() <= 6));
    }

    #[test]
    fn sequential_mode_matches_batched_mode() {
        // The two execution planes must agree token-for-token.
        let run = |exec: ExecMode| {
            let cfg =
                ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
            let model = Model::new(ModelWeights::random(cfg, 7));
            let mut e = Engine::new(
                model,
                EngineConfig::new(CacheSpec::gear(4)).with_exec(exec),
            );
            // ≥ MIN_FANOUT requests so the batched mode actually threads.
            for i in 0..9 {
                e.submit(GenRequest::greedy(i, vec![1, 2, 3 + (i % 7) as u32], 12));
            }
            let mut res = e.run_to_completion();
            res.sort_by_key(|r| r.id);
            res.into_iter().map(|r| (r.id, r.output, r.finish)).collect::<Vec<_>>()
        };
        assert_eq!(run(ExecMode::Sequential), run(ExecMode::Batched));
    }

    #[test]
    fn pipelined_mode_matches_sequential_mode() {
        // The pipeline plane has no minimum fan-out: even a single request
        // splits across layer stages — and must still match the reference
        // token-for-token.
        let run = |exec: ExecMode, n_reqs: u64| {
            let cfg =
                ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
            let model = Model::new(ModelWeights::random(cfg, 7));
            let mut e = Engine::new(
                model,
                EngineConfig::new(CacheSpec::gear(4))
                    .with_exec(exec)
                    .with_pipeline_stages(2),
            );
            for i in 0..n_reqs {
                e.submit(GenRequest::greedy(i, vec![1, 2, 3 + (i % 7) as u32], 12));
            }
            let mut res = e.run_to_completion();
            res.sort_by_key(|r| r.id);
            res.into_iter().map(|r| (r.id, r.output, r.finish)).collect::<Vec<_>>()
        };
        for n in [1u64, 9] {
            assert_eq!(run(ExecMode::Sequential, n), run(ExecMode::Pipelined, n), "n_reqs {n}");
        }
    }

    #[test]
    fn hybrid_mode_matches_sequential_mode() {
        // Hybrid picks a plane per sweep; with the threshold in the middle
        // of the batch-size range the run actually switches (the batch
        // decays as requests finish), and the stream must still match the
        // reference token-for-token.
        let run = |exec: ExecMode, n_reqs: u64| {
            let cfg =
                ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
            let model = Model::new(ModelWeights::random(cfg, 7));
            let mut e = Engine::new(
                model,
                EngineConfig::new(CacheSpec::gear(4))
                    .with_exec(exec)
                    .with_pipeline_stages(2)
                    .with_hybrid_threshold(4),
            );
            for i in 0..n_reqs {
                // Staggered lengths so the decode batch shrinks through
                // the threshold as shorter requests finish.
                e.submit(GenRequest::greedy(i, vec![1, 2, 3 + (i % 7) as u32], 4 + i as usize));
            }
            let mut res = e.run_to_completion();
            res.sort_by_key(|r| r.id);
            let metrics = e.metrics.clone();
            (res.into_iter().map(|r| (r.id, r.output, r.finish)).collect::<Vec<_>>(), metrics)
        };
        for n in [1u64, 9] {
            let (seq, _) = run(ExecMode::Sequential, n);
            let (hyb, m) = run(ExecMode::Hybrid, n);
            assert_eq!(seq, hyb, "n_reqs {n}");
            if n == 9 {
                assert!(m.hybrid_batched_sweeps > 0, "large batches must chunk");
                assert!(m.hybrid_pipelined_sweeps > 0, "small batches must pipeline");
                assert!(m.hybrid_switches >= 1, "the batch decay must switch planes");
            }
        }
    }

    #[test]
    fn tight_budget_serializes_requests() {
        // Budget fits ~one FP16 request: engine must still finish all by
        // serializing, never exceeding the budget.
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
        let one_req = cfg.fp16_kv_bytes(4 + 8); // prompt 4 + 8 new tokens
        let mut e = tiny_engine(CacheSpec::Fp16, one_req + one_req / 2);
        for i in 0..4 {
            e.submit(GenRequest::greedy(i, vec![1, 2, 3, 4], 8));
        }
        let results = e.run_to_completion();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.finish != FinishReason::OutOfMemory));
        assert!(e.metrics.peak_cache_bytes <= one_req + one_req / 2);
        assert_eq!(e.metrics.max_concurrency, 1);
    }

    #[test]
    fn impossible_request_reports_oom() {
        let mut e = tiny_engine(CacheSpec::Fp16, 64); // absurdly small
        e.submit(GenRequest::greedy(1, vec![1, 2, 3, 4, 5, 6], 8));
        let results = e.run_to_completion();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].finish, FinishReason::OutOfMemory);
    }

    #[test]
    fn gear_cache_admits_more_than_fp16() {
        // The core serving claim: under the same budget, the compressed
        // cache sustains higher concurrency. Needs realistic head dims
        // (d_H ≥ 32), otherwise the low-rank overhead dominates the tiny
        // matrices and nothing compresses.
        let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 128 };
        let prompt: Vec<u32> = (0..40).map(|i| (i % 10) + 3).collect();
        let budget = cfg.fp16_kv_bytes(40 + 24) * 2; // ~2 FP16 requests
        let run = |spec: CacheSpec| {
            let model = Model::new(ModelWeights::random(cfg, 7));
            let mut e = Engine::new(
                model,
                EngineConfig::new(spec).with_budget(budget).with_max_batch(8),
            );
            for i in 0..6 {
                e.submit(GenRequest::greedy(i, prompt.clone(), 24));
            }
            let res = e.run_to_completion();
            assert_eq!(res.len(), 6);
            assert!(res.iter().all(|r| r.finish != FinishReason::OutOfMemory));
            e.metrics.max_concurrency
        };
        let fp16 = run(CacheSpec::Fp16);
        let gear = run(CacheSpec::Compressed {
            method: crate::gear::Method::GearL {
                bits: 2,
                backbone: crate::gear::compose::Backbone::Kivi(16),
                r: 2,
            },
            buffer: 8,
            prefill_rank: 2,
            decode_rank: 2,
        });
        assert!(gear > fp16, "gear concurrency {gear} !> fp16 {fp16}");
    }

    /// The point of chunked prefill: an arriving long prompt must not
    /// stall the active batch. Every sweep that advances the long
    /// request's prefill must also decode the already-active request.
    #[test]
    fn decode_continues_while_long_prompt_prefills() {
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 256 };
        let model = Model::new(ModelWeights::random(cfg, 7));
        let mut e =
            Engine::new(model, EngineConfig::new(CacheSpec::Fp16).with_prefill_chunk(16));

        // A short-prompt request starts decoding first (no stop tokens, so
        // it keeps producing for the whole observation window).
        let mut short = GenRequest::greedy(0, vec![1, 2, 3], 64);
        short.stop_tokens.clear();
        e.submit(short);
        while e.metrics.generated_tokens == 0 {
            e.step();
        }

        // A long prompt arrives: 160 tokens = 10 chunks of 16.
        let mut long =
            GenRequest::greedy(1, (0..160).map(|i| (i % 10) + 3).collect(), 4);
        long.stop_tokens.clear();
        e.submit(long);

        let mut prefill_sweeps = 0;
        loop {
            let g0 = e.metrics.generated_tokens;
            e.step();
            if e.active_prefilling() > 0 {
                prefill_sweeps += 1;
                assert!(
                    e.metrics.generated_tokens > g0,
                    "decode stalled during sweep {prefill_sweeps} of the long prefill"
                );
            } else {
                break;
            }
        }
        assert!(
            prefill_sweeps >= 8,
            "expected ~9 interleaved prefill sweeps, got {prefill_sweeps}"
        );
        assert!(e.metrics.prefill_chunks >= 10);

        let results = e.run_to_completion();
        assert_eq!(results.len(), 2);
        assert_eq!(e.budget_used(), 0, "all reservations must drain");
    }

    #[test]
    fn stop_token_ends_generation() {
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        // Stop on every token -> zero-length outputs.
        let mut req = GenRequest::greedy(1, vec![1, 2], 8);
        req.stop_tokens = (0..13).collect();
        e.submit(req);
        let r = e.run_to_completion().pop().unwrap();
        assert_eq!(r.output.len(), 0);
        assert_eq!(r.finish, FinishReason::Stop);
    }
}
