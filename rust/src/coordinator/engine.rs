//! The serving engine: continuous batching over a byte-budgeted cache pool.
//!
//! Scheduling policy (vLLM-flavored):
//! 1. **Admission** — before every decode sweep, waiting requests are
//!    admitted FCFS while (a) the active set is below `max_batch` and
//!    (b) the memory budget can hold a conservative estimate of the
//!    request's cache at full length.
//! 2. **Decode sweep** — every active request advances one token; cache
//!    reservations are adjusted to real bytes after each step.
//! 3. **Preemption** — if a reservation can't grow, the *youngest* active
//!    request is preempted: its cache is dropped, and it requeues at the
//!    front to re-prefill later (recompute preemption, as in vLLM). A
//!    request that cannot fit even alone finishes as `OutOfMemory`.
//!
//! The engine is deterministic: FCFS admission, fixed iteration order, and
//! per-request seeded samplers.

use std::collections::VecDeque;
use std::time::Instant;

use crate::kvcache::budget::MemoryBudget;
use crate::kvcache::{CacheSpec, RequestCache};
use crate::model::Model;
use crate::util::rng::Rng;

use super::metrics::EngineMetrics;
use super::request::{FinishReason, GenRequest, GenResult};

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub spec: CacheSpec,
    /// Max simultaneously-active requests.
    pub max_batch: usize,
    /// KV-cache byte budget (the "GPU memory" left after weights).
    pub budget_bytes: usize,
    /// Seed for sampling RNGs.
    pub seed: u64,
}

impl EngineConfig {
    pub fn new(spec: CacheSpec) -> EngineConfig {
        EngineConfig { spec, max_batch: 64, budget_bytes: usize::MAX, seed: 0x5EED }
    }

    pub fn with_budget(mut self, bytes: usize) -> Self {
        self.budget_bytes = bytes;
        self
    }

    pub fn with_max_batch(mut self, b: usize) -> Self {
        self.max_batch = b;
        self
    }
}

struct Active {
    req: GenRequest,
    cache: RequestCache,
    /// Bytes currently reserved in the budget for this request.
    reserved: usize,
    output: Vec<u32>,
    /// Next token to feed (last sampled).
    next_token: u32,
    /// Position of the next decode step.
    pos: usize,
    preemptions: usize,
    rng: Rng,
    enqueued_at: Instant,
    started_at: Instant,
}

/// Synchronous serving engine.
pub struct Engine {
    model: Model,
    cfg: EngineConfig,
    budget: MemoryBudget,
    waiting: VecDeque<(GenRequest, Instant, usize)>,
    active: Vec<Active>,
    finished: Vec<GenResult>,
    pub metrics: EngineMetrics,
}

impl Engine {
    pub fn new(model: Model, cfg: EngineConfig) -> Engine {
        let budget = MemoryBudget::new(cfg.budget_bytes);
        Engine {
            model,
            cfg,
            budget,
            waiting: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            metrics: EngineMetrics::default(),
        }
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.waiting.push_back((req, Instant::now(), 0));
    }

    /// Conservative cache-size estimate for admission: prompt + full
    /// generation at the configured compression ratio, via the analytic
    /// size model (FP16 methods estimate at 100%).
    fn estimate_bytes(&self, prompt_len: usize, max_new: usize) -> usize {
        let c = self.model.config();
        let n = prompt_len + max_new;
        let frac = match self.cfg.spec {
            CacheSpec::Fp16 => 1.0,
            CacheSpec::Compressed { method, buffer, .. } => {
                // 1.25 safety factor: decode-phase chunks (n_b tokens at
                // rank r_g) carry proportionally more low-rank/meta overhead
                // than the analytic whole-matrix prediction.
                1.25 * crate::gear::size::predict_cache_frac(
                    method,
                    n,
                    c.d_model,
                    c.n_layers,
                    c.n_heads,
                    buffer,
                )
            }
            CacheSpec::H2o { keep, .. } => keep.max(0.05) + 0.05,
        };
        (c.fp16_kv_bytes(n) as f64 * frac).ceil() as usize
    }

    fn try_admit(&mut self) {
        while self.active.len() < self.cfg.max_batch {
            let Some((req, enq, preemptions)) = self.waiting.front().cloned() else { break };
            let est = self.estimate_bytes(req.prompt.len(), req.max_new_tokens);
            if !self.budget.try_reserve(est) {
                // Can it ever fit? If nothing is active and it still fails,
                // reject rather than deadlock.
                if self.active.is_empty() {
                    self.waiting.pop_front();
                    self.metrics.requests_oom += 1;
                    self.finished.push(GenResult {
                        id: req.id,
                        output: Vec::new(),
                        finish: FinishReason::OutOfMemory,
                        prompt_len: req.prompt.len(),
                        preemptions,
                        queue_secs: enq.elapsed().as_secs_f64(),
                        run_secs: 0.0,
                    });
                    continue;
                }
                break;
            }
            self.waiting.pop_front();

            // Prefill.
            let c = self.model.config();
            let mut cache = RequestCache::new(&self.cfg.spec, c.n_layers, c.d_model, c.n_heads);
            let started_at = Instant::now();
            let out = self.model.prefill(&req.prompt, &mut cache);
            // Swap the estimate for real bytes.
            let real = cache.nbytes();
            let est_after = if real > est { real } else { est };
            // Keep the conservative estimate reserved (it covers growth);
            // shrink only if the estimate was below reality.
            if real > est {
                // Rare (estimate is conservative); grow reservation.
                let _ = self.budget.adjust(est, real);
            }
            let mut rng = Rng::new(self.cfg.seed ^ req.id.wrapping_mul(0x9E3779B97F4A7C15));
            let first = req.sampler.sample(&out.last_logits, &mut rng);
            let pos = req.prompt.len();
            self.metrics.prompt_tokens += pos;
            self.active.push(Active {
                req,
                cache,
                reserved: est_after,
                output: Vec::new(),
                next_token: first,
                pos,
                preemptions,
                rng,
                enqueued_at: enq,
                started_at,
            });
            self.metrics.max_concurrency = self.metrics.max_concurrency.max(self.active.len());
        }
    }

    /// Run one decode sweep over all active requests. Returns the number of
    /// tokens generated this step.
    fn sweep(&mut self) -> usize {
        let mut produced = 0;
        let mut idx = 0;
        while idx < self.active.len() {
            let a = &mut self.active[idx];
            // The sampled token from the previous step/prefill is emitted
            // first; stop tokens never enter the output.
            if a.req.stop_tokens.contains(&a.next_token) {
                Self::finish_at(
                    &mut self.active,
                    idx,
                    &mut self.finished,
                    &mut self.metrics,
                    &self.budget,
                    FinishReason::Stop,
                );
                continue;
            }
            a.output.push(a.next_token);
            produced += 1;
            self.metrics.generated_tokens += 1;
            let done_len = a.output.len() >= a.req.max_new_tokens;
            let done_ctx = a.pos + 1 >= self.model.config().max_seq;
            if done_len || done_ctx {
                Self::finish_at(
                    &mut self.active,
                    idx,
                    &mut self.finished,
                    &mut self.metrics,
                    &self.budget,
                    FinishReason::Length,
                );
                continue;
            }
            let logits = self.model.decode_step(a.next_token, a.pos, &mut a.cache);
            a.pos += 1;
            a.next_token = a.req.sampler.sample(&logits, &mut a.rng);

            // Track real cache growth against the reservation.
            let real = a.cache.nbytes();
            if real > a.reserved {
                let old = a.reserved;
                if self.budget.adjust(old, real) {
                    a.reserved = real;
                } else {
                    // Budget exhausted: preempt the youngest active request.
                    self.preempt_youngest();
                    // Current index may have shifted; restart the sweep scan.
                    idx = 0;
                    continue;
                }
            }
            idx += 1;
        }
        produced
    }

    fn finish_at(
        active: &mut Vec<Active>,
        idx: usize,
        finished: &mut Vec<GenResult>,
        metrics: &mut EngineMetrics,
        budget: &MemoryBudget,
        finish: FinishReason,
    ) {
        let a = active.swap_remove(idx);
        budget.release(a.reserved);
        metrics.requests_finished += 1;
        finished.push(GenResult {
            id: a.req.id,
            output: a.output,
            finish,
            prompt_len: a.req.prompt.len(),
            preemptions: a.preemptions,
            queue_secs: (a.started_at - a.enqueued_at).as_secs_f64(),
            run_secs: a.started_at.elapsed().as_secs_f64(),
        });
    }

    fn preempt_youngest(&mut self) {
        // Youngest = last admitted (highest started_at).
        if let Some(idx) = (0..self.active.len()).max_by_key(|&i| self.active[i].started_at) {
            let a = self.active.swap_remove(idx);
            self.budget.release(a.reserved);
            // A sole request that still can't grow will never fit: fail it
            // rather than livelock on preempt/re-admit.
            if self.active.is_empty() {
                self.metrics.requests_oom += 1;
                self.finished.push(GenResult {
                    id: a.req.id,
                    output: a.output,
                    finish: FinishReason::OutOfMemory,
                    prompt_len: a.req.prompt.len(),
                    preemptions: a.preemptions,
                    queue_secs: (a.started_at - a.enqueued_at).as_secs_f64(),
                    run_secs: a.started_at.elapsed().as_secs_f64(),
                });
                return;
            }
            self.metrics.requests_preempted += 1;
            // Requeue at the front with its original enqueue time.
            self.waiting.push_front((a.req, a.enqueued_at, a.preemptions + 1));
        }
    }

    /// Drive the engine until all submitted work is done; returns results
    /// in finish order.
    pub fn run_to_completion(&mut self) -> Vec<GenResult> {
        let t0 = Instant::now();
        // Reset component timers so the breakdown covers only this run.
        let _ = crate::gear::take_phase_timings();
        self.budget.reset_peak();
        loop {
            self.try_admit();
            if self.active.is_empty() {
                if self.waiting.is_empty() {
                    break;
                }
                // Nothing active and nothing admittable -> the head request
                // can't fit; try_admit handles the OOM case, so reaching
                // here means a transient state. Avoid a spin.
                continue;
            }
            self.sweep();
        }
        self.metrics.wall += t0.elapsed();
        self.metrics.peak_cache_bytes = self.metrics.peak_cache_bytes.max(self.budget.peak());
        self.metrics.phases.merge(&crate::gear::take_phase_timings());
        std::mem::take(&mut self.finished)
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.active.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    fn tiny_engine(spec: CacheSpec, budget: usize) -> Engine {
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
        let model = Model::new(ModelWeights::random(cfg, 7));
        Engine::new(model, EngineConfig::new(spec).with_budget(budget))
    }

    #[test]
    fn serves_multiple_requests() {
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        for i in 0..5 {
            e.submit(GenRequest::greedy(i, vec![1, 2, 3, (i % 10) as u32 + 3], 8));
        }
        let results = e.run_to_completion();
        assert_eq!(results.len(), 5);
        for r in &results {
            assert!(matches!(r.finish, FinishReason::Stop | FinishReason::Length));
            assert!(r.output.len() <= 8);
        }
        assert_eq!(e.metrics.requests_finished, 5);
        assert!(e.metrics.generated_tokens > 0);
        assert!(e.metrics.max_concurrency >= 2);
    }

    #[test]
    fn identical_requests_identical_outputs() {
        // Determinism: same id -> same sampling path.
        let run = || {
            let mut e = tiny_engine(CacheSpec::gear(4), usize::MAX);
            e.submit(GenRequest::greedy(42, vec![1, 4, 6, 8], 10));
            e.run_to_completion().pop().unwrap().output
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn tight_budget_serializes_requests() {
        // Budget fits ~one FP16 request: engine must still finish all by
        // serializing, never exceeding the budget.
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
        let one_req = cfg.fp16_kv_bytes(4 + 8); // prompt 4 + 8 new tokens
        let mut e = tiny_engine(CacheSpec::Fp16, one_req + one_req / 2);
        for i in 0..4 {
            e.submit(GenRequest::greedy(i, vec![1, 2, 3, 4], 8));
        }
        let results = e.run_to_completion();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.finish != FinishReason::OutOfMemory));
        assert!(e.metrics.peak_cache_bytes <= one_req + one_req / 2);
        assert_eq!(e.metrics.max_concurrency, 1);
    }

    #[test]
    fn impossible_request_reports_oom() {
        let mut e = tiny_engine(CacheSpec::Fp16, 64); // absurdly small
        e.submit(GenRequest::greedy(1, vec![1, 2, 3, 4, 5, 6], 8));
        let results = e.run_to_completion();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].finish, FinishReason::OutOfMemory);
    }

    #[test]
    fn gear_cache_admits_more_than_fp16() {
        // The core serving claim: under the same budget, the compressed
        // cache sustains higher concurrency. Needs realistic head dims
        // (d_H ≥ 32), otherwise the low-rank overhead dominates the tiny
        // matrices and nothing compresses.
        let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 128 };
        let prompt: Vec<u32> = (0..40).map(|i| (i % 10) + 3).collect();
        let budget = cfg.fp16_kv_bytes(40 + 24) * 2; // ~2 FP16 requests
        let run = |spec: CacheSpec| {
            let model = Model::new(ModelWeights::random(cfg, 7));
            let mut e = Engine::new(
                model,
                EngineConfig::new(spec).with_budget(budget).with_max_batch(8),
            );
            for i in 0..6 {
                e.submit(GenRequest::greedy(i, prompt.clone(), 24));
            }
            let res = e.run_to_completion();
            assert_eq!(res.len(), 6);
            assert!(res.iter().all(|r| r.finish != FinishReason::OutOfMemory));
            e.metrics.max_concurrency
        };
        let fp16 = run(CacheSpec::Fp16);
        let gear = run(CacheSpec::Compressed {
            method: crate::gear::Method::GearL {
                bits: 2,
                backbone: crate::gear::compose::Backbone::Kivi(16),
                r: 2,
            },
            buffer: 8,
            prefill_rank: 2,
            decode_rank: 2,
        });
        assert!(gear > fp16, "gear concurrency {gear} !> fp16 {fp16}");
    }

    #[test]
    fn stop_token_ends_generation() {
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        // Stop on every token -> zero-length outputs.
        let mut req = GenRequest::greedy(1, vec![1, 2], 8);
        req.stop_tokens = (0..13).collect();
        e.submit(req);
        let r = e.run_to_completion().pop().unwrap();
        assert_eq!(r.output.len(), 0);
        assert_eq!(r.finish, FinishReason::Stop);
    }
}
