//! The serving engine: a two-plane architecture over a byte-budgeted cache
//! pool.
//!
//! * **Scheduling plane** ([`super::scheduler`]) — admission, budget
//!   accounting, preemption, finish bookkeeping. Pure policy, FCFS
//!   deterministic, unchanged from the single-plane engine.
//! * **Execution plane** ([`super::executor`]) — one decode step for the
//!   *whole* active set as a single batched, layer-major model call,
//!   chunked across worker threads with a fixed-order reduction.
//!
//! A sweep has three phases:
//! 1. **Emit** (policy, sequential): each active request's previously
//!    sampled token is emitted; stop/length/context finishes retire.
//! 2. **Execute**: the surviving requests advance one token in a single
//!    [`BatchExecutor::run`] call.
//! 3. **Commit** (policy, sequential, fixed order): per request — sample
//!    the next token, grow its cache reservation; on budget exhaustion the
//!    youngest active request is preempted (recompute preemption) and the
//!    adjustment retries.
//!
//! Phases 1 and 3 are sequential and order-fixed, and phase 2 is
//! bit-identical between [`ExecMode::Sequential`] and [`ExecMode::Batched`]
//! (each request's forward touches only its own state), so the two modes
//! produce identical token streams, finish reasons, and peak cache bytes —
//! `tests/batched_vs_sequential.rs` pins this.
//!
//! Budget semantics: reservations are checked in the commit phase, *after*
//! the batch decodes, so real cache bytes may transiently exceed the
//! configured budget by up to one step's growth across the active set
//! (the single-plane engine bounded the overshoot to one request's step).
//! `peak_cache_bytes` tracks reservations, as it always has. Pre-reserving
//! per-step headroom before phase 2 would close the window — ROADMAP.

use std::time::Instant;

use crate::kvcache::CacheSpec;
use crate::model::Model;

use super::executor::{BatchExecutor, ExecMode};
use super::metrics::EngineMetrics;
use super::request::{FinishReason, GenRequest, GenResult};
use super::scheduler::{ActiveRequest, Scheduler};

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub spec: CacheSpec,
    /// Max simultaneously-active requests.
    pub max_batch: usize,
    /// KV-cache byte budget (the "GPU memory" left after weights).
    pub budget_bytes: usize,
    /// Seed for sampling RNGs.
    pub seed: u64,
    /// How decode sweeps execute. `Batched` is the default; `Sequential`
    /// is the single-thread reference with identical results.
    pub exec: ExecMode,
}

impl EngineConfig {
    pub fn new(spec: CacheSpec) -> EngineConfig {
        EngineConfig {
            spec,
            max_batch: 64,
            budget_bytes: usize::MAX,
            seed: 0x5EED,
            exec: ExecMode::Batched,
        }
    }

    pub fn with_budget(mut self, bytes: usize) -> Self {
        self.budget_bytes = bytes;
        self
    }

    pub fn with_max_batch(mut self, b: usize) -> Self {
        self.max_batch = b;
        self
    }

    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }
}

/// Synchronous serving engine: scheduler (policy) + batch executor
/// (execution) around one model.
pub struct Engine {
    model: Model,
    scheduler: Scheduler,
    executor: BatchExecutor,
    active: Vec<ActiveRequest>,
    finished: Vec<GenResult>,
    pub metrics: EngineMetrics,
}

impl Engine {
    pub fn new(model: Model, cfg: EngineConfig) -> Engine {
        let executor = BatchExecutor::new(&model, cfg.exec);
        Engine {
            scheduler: Scheduler::new(cfg),
            executor,
            model,
            active: Vec::new(),
            finished: Vec::new(),
            metrics: EngineMetrics::default(),
        }
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.scheduler.submit(req);
    }

    /// Run one decode sweep over all active requests. Returns the number of
    /// tokens generated this step.
    fn sweep(&mut self) -> usize {
        // Phase 1 — emit previously sampled tokens; retire finishes. The
        // sampled token from the previous step/prefill is emitted first;
        // stop tokens never enter the output.
        let max_seq = self.model.config().max_seq;
        let mut produced = 0;
        let mut idx = 0;
        while idx < self.active.len() {
            let stopped = {
                let a = &self.active[idx];
                a.req.stop_tokens.contains(&a.next_token)
            };
            if stopped {
                self.finish_at(idx, FinishReason::Stop);
                continue;
            }
            let done = {
                let a = &mut self.active[idx];
                a.output.push(a.next_token);
                a.output.len() >= a.req.max_new_tokens || a.pos + 1 >= max_seq
            };
            produced += 1;
            self.metrics.generated_tokens += 1;
            if done {
                self.finish_at(idx, FinishReason::Length);
                continue;
            }
            idx += 1;
        }
        if self.active.is_empty() {
            return produced;
        }

        // Phase 2 — one batched decode step for every survivor. Requests
        // are re-found by admission serial afterwards (caller-chosen
        // `req.id`s need not be unique; serials are).
        let serials: Vec<u64> = self.active.iter().map(|a| a.serial).collect();
        let logits = {
            let mut refs: Vec<&mut ActiveRequest> = self.active.iter_mut().collect();
            self.executor.run(&self.model, &mut refs)
        };

        // Phase 3 — commit in batch order: sample, grow reservations,
        // preempt on exhaustion. A request preempted by an earlier commit
        // in this loop is skipped (its state was dropped and requeued).
        for (lg, serial) in logits.into_iter().zip(serials) {
            let Some(i) = self.active.iter().position(|a| a.serial == serial) else { continue };
            let real = {
                let a = &mut self.active[i];
                a.pos += 1;
                a.next_token = a.req.sampler.sample(&lg, &mut a.rng);
                a.cache.nbytes()
            };
            loop {
                let Some(i) = self.active.iter().position(|a| a.serial == serial) else { break };
                let old = self.active[i].reserved;
                if real <= old {
                    break;
                }
                if self.scheduler.budget.adjust(old, real) {
                    self.active[i].reserved = real;
                    break;
                }
                // Budget exhausted: preempt the youngest and retry. Each
                // preemption shrinks the active set, so this terminates —
                // in the worst case the committing request itself is
                // preempted (or OOM-finished when it is the last one).
                self.scheduler.preempt_youngest(
                    &mut self.active,
                    &mut self.finished,
                    &mut self.metrics,
                );
            }
        }
        produced
    }

    fn finish_at(&mut self, idx: usize, finish: FinishReason) {
        let a = self.active.swap_remove(idx);
        self.scheduler.budget.release(a.reserved);
        self.metrics.requests_finished += 1;
        self.finished.push(a.into_result(finish));
    }

    /// Drive the engine until all submitted work is done; returns results
    /// in finish order.
    pub fn run_to_completion(&mut self) -> Vec<GenResult> {
        let t0 = Instant::now();
        // Reset component timers so the breakdown covers only this run.
        let _ = crate::gear::take_phase_timings();
        self.scheduler.budget.reset_peak();
        loop {
            self.scheduler.try_admit(
                &self.model,
                &mut self.active,
                &mut self.finished,
                &mut self.metrics,
            );
            if self.active.is_empty() {
                if self.scheduler.waiting_len() == 0 {
                    break;
                }
                // Nothing active and nothing admittable -> the head request
                // can't fit; try_admit handles the OOM case, so reaching
                // here means a transient state. Avoid a spin.
                continue;
            }
            self.sweep();
        }
        self.metrics.wall += t0.elapsed();
        self.metrics.peak_cache_bytes =
            self.metrics.peak_cache_bytes.max(self.scheduler.budget.peak());
        self.metrics.phases.merge(&crate::gear::take_phase_timings());
        std::mem::take(&mut self.finished)
    }

    pub fn pending(&self) -> usize {
        self.scheduler.waiting_len() + self.active.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    fn tiny_engine(spec: CacheSpec, budget: usize) -> Engine {
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
        let model = Model::new(ModelWeights::random(cfg, 7));
        Engine::new(model, EngineConfig::new(spec).with_budget(budget))
    }

    #[test]
    fn serves_multiple_requests() {
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        for i in 0..5 {
            e.submit(GenRequest::greedy(i, vec![1, 2, 3, (i % 10) as u32 + 3], 8));
        }
        let results = e.run_to_completion();
        assert_eq!(results.len(), 5);
        for r in &results {
            assert!(matches!(r.finish, FinishReason::Stop | FinishReason::Length));
            assert!(r.output.len() <= 8);
        }
        assert_eq!(e.metrics.requests_finished, 5);
        assert!(e.metrics.generated_tokens > 0);
        assert!(e.metrics.max_concurrency >= 2);
    }

    #[test]
    fn identical_requests_identical_outputs() {
        // Determinism: same id -> same sampling path.
        let run = || {
            let mut e = tiny_engine(CacheSpec::gear(4), usize::MAX);
            e.submit(GenRequest::greedy(42, vec![1, 4, 6, 8], 10));
            e.run_to_completion().pop().unwrap().output
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn duplicate_request_ids_both_served() {
        // Caller-chosen ids need not be unique: the commit phase keys on
        // admission serials, so twin ids must not cross-contaminate state.
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        e.submit(GenRequest::greedy(7, vec![1, 2, 3], 6));
        e.submit(GenRequest::greedy(7, vec![1, 2, 3], 6));
        let results = e.run_to_completion();
        assert_eq!(results.len(), 2);
        // Same id + same prompt -> same sampler seed -> identical streams.
        assert_eq!(results[0].output, results[1].output);
        assert!(results.iter().all(|r| r.output.len() <= 6));
    }

    #[test]
    fn sequential_mode_matches_batched_mode() {
        // The two execution planes must agree token-for-token.
        let run = |exec: ExecMode| {
            let cfg =
                ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
            let model = Model::new(ModelWeights::random(cfg, 7));
            let mut e = Engine::new(
                model,
                EngineConfig::new(CacheSpec::gear(4)).with_exec(exec),
            );
            // ≥ MIN_FANOUT requests so the batched mode actually threads.
            for i in 0..9 {
                e.submit(GenRequest::greedy(i, vec![1, 2, 3 + (i % 7) as u32], 12));
            }
            let mut res = e.run_to_completion();
            res.sort_by_key(|r| r.id);
            res.into_iter().map(|r| (r.id, r.output, r.finish)).collect::<Vec<_>>()
        };
        assert_eq!(run(ExecMode::Sequential), run(ExecMode::Batched));
    }

    #[test]
    fn tight_budget_serializes_requests() {
        // Budget fits ~one FP16 request: engine must still finish all by
        // serializing, never exceeding the budget.
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 96 };
        let one_req = cfg.fp16_kv_bytes(4 + 8); // prompt 4 + 8 new tokens
        let mut e = tiny_engine(CacheSpec::Fp16, one_req + one_req / 2);
        for i in 0..4 {
            e.submit(GenRequest::greedy(i, vec![1, 2, 3, 4], 8));
        }
        let results = e.run_to_completion();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.finish != FinishReason::OutOfMemory));
        assert!(e.metrics.peak_cache_bytes <= one_req + one_req / 2);
        assert_eq!(e.metrics.max_concurrency, 1);
    }

    #[test]
    fn impossible_request_reports_oom() {
        let mut e = tiny_engine(CacheSpec::Fp16, 64); // absurdly small
        e.submit(GenRequest::greedy(1, vec![1, 2, 3, 4, 5, 6], 8));
        let results = e.run_to_completion();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].finish, FinishReason::OutOfMemory);
    }

    #[test]
    fn gear_cache_admits_more_than_fp16() {
        // The core serving claim: under the same budget, the compressed
        // cache sustains higher concurrency. Needs realistic head dims
        // (d_H ≥ 32), otherwise the low-rank overhead dominates the tiny
        // matrices and nothing compresses.
        let cfg = ModelConfig { vocab: 13, d_model: 64, n_layers: 2, n_heads: 2, max_seq: 128 };
        let prompt: Vec<u32> = (0..40).map(|i| (i % 10) + 3).collect();
        let budget = cfg.fp16_kv_bytes(40 + 24) * 2; // ~2 FP16 requests
        let run = |spec: CacheSpec| {
            let model = Model::new(ModelWeights::random(cfg, 7));
            let mut e = Engine::new(
                model,
                EngineConfig::new(spec).with_budget(budget).with_max_batch(8),
            );
            for i in 0..6 {
                e.submit(GenRequest::greedy(i, prompt.clone(), 24));
            }
            let res = e.run_to_completion();
            assert_eq!(res.len(), 6);
            assert!(res.iter().all(|r| r.finish != FinishReason::OutOfMemory));
            e.metrics.max_concurrency
        };
        let fp16 = run(CacheSpec::Fp16);
        let gear = run(CacheSpec::Compressed {
            method: crate::gear::Method::GearL {
                bits: 2,
                backbone: crate::gear::compose::Backbone::Kivi(16),
                r: 2,
            },
            buffer: 8,
            prefill_rank: 2,
            decode_rank: 2,
        });
        assert!(gear > fp16, "gear concurrency {gear} !> fp16 {fp16}");
    }

    #[test]
    fn stop_token_ends_generation() {
        let mut e = tiny_engine(CacheSpec::Fp16, usize::MAX);
        // Stop on every token -> zero-length outputs.
        let mut req = GenRequest::greedy(1, vec![1, 2], 8);
        req.stop_tokens = (0..13).collect();
        e.submit(req);
        let r = e.run_to_completion().pop().unwrap();
        assert_eq!(r.output.len(), 0);
        assert_eq!(r.finish, FinishReason::Stop);
    }
}
