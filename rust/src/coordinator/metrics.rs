//! Engine metrics: throughput, latency, memory, and the GEAR component
//! time breakdown (reproduces Fig 3a).

use std::time::Duration;

use crate::util::timing::PhaseTimer;

/// Aggregated over an engine run.
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub requests_finished: usize,
    pub requests_preempted: usize,
    pub requests_oom: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub wall: Duration,
    /// Wall time spent in the sweep prefill phase (chunk execution plus
    /// commit-time compression; subtract it to compare decode planes).
    pub prefill: Duration,
    /// Prefill chunks executed (one request-chunk each). With chunking
    /// disabled (`prefill_chunk >= prompt_len`) this equals the number of
    /// admissions.
    pub prefill_chunks: usize,
    /// Peak KV-cache bytes across the run (from the budget tracker).
    pub peak_cache_bytes: usize,
    /// Wall time attributed to GEAR components (quant/sparse/lowrank) vs
    /// everything else ("other" = model forward + scheduling).
    pub phases: PhaseTimer,
    /// Largest number of simultaneously-active requests observed.
    pub max_concurrency: usize,
    /// Per-sweep decode step latency (executor decode + flush commit point
    /// + sampling/settle), one sample per sweep that decoded at least one
    /// request. Summarize with [`Self::step_latency_pct`].
    pub step_latencies: Vec<Duration>,
    /// Asynchronous segment-compression jobs submitted at commit points
    /// (one per sealed request-layer). Deterministic: both exec modes
    /// submit the identical job sequence.
    pub flush_jobs: usize,
    /// Wall time the engine spent *blocked* at flush join points — waiting
    /// for a running job, or compressing a still-queued job inline (always
    /// the case in `ExecMode::Sequential`, which is therefore the blocking
    /// baseline this stall is compared against). This is the residual
    /// compression stall left after the submit/join overlap.
    pub flush_stall: Duration,
    /// Compression wall time that completed off the engine's critical path:
    /// for each joined job, its compression time minus whatever the join
    /// still had to wait. Zero in `ExecMode::Sequential`; with a pool and
    /// enough idle gaps this approaches the total compression time — the
    /// overlap win `bench_throughput --compare` reports.
    pub flush_overlap_won: Duration,
    /// Per-pipeline-stage busy time accumulated across sweeps
    /// (`ExecMode::Pipelined` only; empty otherwise). Index = stage.
    pub stage_busy: Vec<Duration>,
    /// Per-pipeline-stage bubble time: wall the stage spent waiting on its
    /// upstream hand-off. `stage_bubble[0]` is always zero (stage 0 has no
    /// upstream).
    pub stage_bubble: Vec<Duration>,
}

impl EngineMetrics {
    /// Generated tokens per second of wall time.
    pub fn throughput(&self) -> f64 {
        self.generated_tokens as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Generated tokens per second of *decode* wall time (prefill
    /// excluded) — the decode-plane comparison metric.
    pub fn decode_throughput(&self) -> f64 {
        let secs = self.wall.saturating_sub(self.prefill).as_secs_f64();
        self.generated_tokens as f64 / secs.max(1e-9)
    }

    /// Step-latency percentile over the recorded decode sweeps
    /// (nearest-rank on the sorted samples; `q` in `[0, 1]`). Zero when no
    /// sweep decoded.
    pub fn step_latency_pct(&self, q: f64) -> Duration {
        if self.step_latencies.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.step_latencies.clone();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        v[idx]
    }

    /// Median per-sweep decode step latency.
    pub fn step_p50(&self) -> Duration {
        self.step_latency_pct(0.50)
    }

    /// Tail (p99) per-sweep decode step latency.
    pub fn step_p99(&self) -> Duration {
        self.step_latency_pct(0.99)
    }

    /// Accumulate one sweep's per-stage `(busy, bubble)` pipeline timings.
    /// No-op on an empty slice, so the non-pipelined planes cost nothing.
    pub fn record_stage_times(&mut self, times: &[(Duration, Duration)]) {
        if times.is_empty() {
            return;
        }
        if self.stage_busy.len() < times.len() {
            self.stage_busy.resize(times.len(), Duration::ZERO);
            self.stage_bubble.resize(times.len(), Duration::ZERO);
        }
        for (s, &(busy, bubble)) in times.iter().enumerate() {
            self.stage_busy[s] += busy;
            self.stage_bubble[s] += bubble;
        }
    }

    /// Per-stage occupancy `busy / (busy + bubble)` in `[0, 1]` — how much
    /// of each pipeline stage's wall went to forward work rather than
    /// waiting on the upstream hand-off. Empty unless the engine ran
    /// `ExecMode::Pipelined`.
    pub fn stage_occupancy(&self) -> Vec<f64> {
        self.stage_busy
            .iter()
            .zip(&self.stage_bubble)
            .map(|(&b, &w)| {
                let total = (b + w).as_secs_f64();
                if total <= 0.0 { 0.0 } else { b.as_secs_f64() / total }
            })
            .collect()
    }

    /// Fig 3a rows: (component, seconds, fraction).
    ///
    /// Component timings accumulate across *all* threads — since PR 4,
    /// worker-side flush jobs run overlapped with the forward pass, so the
    /// accounted component time can legitimately exceed wall time. Fractions
    /// are therefore taken over `max(wall, accounted)`: they stay
    /// non-negative and sum to exactly 1 in both regimes. The residual
    /// "other (fwd)" row is clamped at zero, and any overlapped excess
    /// (`accounted − wall`, the compression that ran off the critical path)
    /// is reported as its own informational row with fraction 0 — it is a
    /// re-count of time already inside the component rows, not an extra
    /// share of the denominator.
    pub fn time_breakdown(&self) -> Vec<(String, f64, f64)> {
        let wall = self.wall.as_secs_f64();
        let accounted: f64 = ["quant", "lowrank", "sparse"]
            .iter()
            .map(|n| self.phases.get(n).as_secs_f64())
            .sum();
        let denom = wall.max(accounted).max(1e-12);
        let mut rows = Vec::new();
        for name in ["quant", "lowrank", "sparse"] {
            let secs = self.phases.get(name).as_secs_f64();
            rows.push((name.to_string(), secs, secs / denom));
        }
        let other = (wall - accounted).max(0.0);
        rows.push(("other (fwd)".to_string(), other, other / denom));
        let overlapped = (accounted - wall).max(0.0);
        rows.push(("overlapped (off critical path)".to_string(), overlapped, 0.0));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = EngineMetrics {
            generated_tokens: 100,
            wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn step_latency_percentiles() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.step_p50(), Duration::ZERO);
        assert_eq!(m.step_p99(), Duration::ZERO);
        // Unsorted on purpose: percentiles sort a copy.
        for ms in [40u64, 10, 30, 20, 50] {
            m.step_latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.step_p50(), Duration::from_millis(30));
        assert_eq!(m.step_p99(), Duration::from_millis(50));
        assert_eq!(m.step_latency_pct(0.0), Duration::from_millis(10));
        assert_eq!(m.step_latency_pct(1.0), Duration::from_millis(50));
    }

    #[test]
    fn breakdown_sums_to_one() {
        let mut m = EngineMetrics {
            wall: Duration::from_millis(100),
            ..Default::default()
        };
        m.phases.add("quant", Duration::from_millis(20));
        m.phases.add("lowrank", Duration::from_millis(10));
        let rows = m.time_breakdown();
        assert_eq!(rows.len(), 5);
        let total: f64 = rows.iter().map(|r| r.2).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!((rows[3].2 - 0.7).abs() < 1e-9, "other = {}", rows[3].2);
        assert_eq!(rows[4].1, 0.0, "no overlap when accounted < wall");
    }

    /// Overlapped flush jobs accumulate component time on worker threads,
    /// so accounted can exceed wall. Fractions must stay non-negative and
    /// sum to 1, with the excess surfaced as the overlap row.
    #[test]
    fn breakdown_overlap_exceeds_wall() {
        let mut m = EngineMetrics {
            wall: Duration::from_millis(100),
            ..Default::default()
        };
        m.phases.add("quant", Duration::from_millis(80));
        m.phases.add("lowrank", Duration::from_millis(50));
        m.phases.add("sparse", Duration::from_millis(30));
        let rows = m.time_breakdown();
        assert_eq!(rows.len(), 5);
        for (name, secs, frac) in &rows {
            assert!(*secs >= 0.0, "{name} seconds negative: {secs}");
            assert!(*frac >= 0.0, "{name} fraction negative: {frac}");
        }
        let total: f64 = rows.iter().map(|r| r.2).sum();
        assert!((total - 1.0).abs() < 1e-9, "fractions sum to {total}");
        assert_eq!(rows[3].1, 0.0, "other clamped at zero");
        // 160 ms accounted − 100 ms wall = 60 ms ran off the critical path.
        assert!((rows[4].1 - 0.060).abs() < 1e-9, "overlap = {}", rows[4].1);
        // Component fractions are over the accounted total in this regime.
        assert!((rows[0].2 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn stage_times_accumulate_and_occupancy() {
        let mut m = EngineMetrics::default();
        m.record_stage_times(&[]);
        assert!(m.stage_busy.is_empty(), "empty slice is a no-op");
        let sweep = [
            (Duration::from_millis(30), Duration::ZERO),
            (Duration::from_millis(10), Duration::from_millis(30)),
        ];
        m.record_stage_times(&sweep);
        m.record_stage_times(&sweep);
        assert_eq!(m.stage_busy, vec![Duration::from_millis(60), Duration::from_millis(20)]);
        assert_eq!(m.stage_bubble, vec![Duration::ZERO, Duration::from_millis(60)]);
        let occ = m.stage_occupancy();
        assert!((occ[0] - 1.0).abs() < 1e-9);
        assert!((occ[1] - 0.25).abs() < 1e-9);
    }
}
