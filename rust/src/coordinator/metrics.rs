//! Engine metrics: throughput, latency, memory, and the GEAR component
//! time breakdown (reproduces Fig 3a).

use std::fmt::Write as _;
use std::time::Duration;

use crate::trace::TraceSummary;
use crate::util::timing::PhaseTimer;

/// Aggregated over an engine run.
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub requests_finished: usize,
    pub requests_preempted: usize,
    pub requests_oom: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub wall: Duration,
    /// Wall time spent in the sweep prefill phase (chunk execution plus
    /// commit-time compression; subtract it to compare decode planes).
    pub prefill: Duration,
    /// Prefill chunks executed (one request-chunk each). With chunking
    /// disabled (`prefill_chunk >= prompt_len`) this equals the number of
    /// admissions.
    pub prefill_chunks: usize,
    /// Peak KV-cache bytes across the run (from the budget tracker).
    pub peak_cache_bytes: usize,
    /// Wall time attributed to GEAR components (quant/sparse/lowrank) vs
    /// everything else ("other" = model forward + scheduling).
    pub phases: PhaseTimer,
    /// Largest number of simultaneously-active requests observed.
    pub max_concurrency: usize,
    /// Per-sweep decode step latency (executor decode + flush commit point
    /// + sampling/settle), one sample per sweep that decoded at least one
    /// request. Summarize with [`Self::step_latency_pct`].
    pub step_latencies: Vec<Duration>,
    /// Asynchronous segment-compression jobs submitted at commit points
    /// (one per sealed request-layer). Deterministic: both exec modes
    /// submit the identical job sequence.
    pub flush_jobs: usize,
    /// Wall time the engine spent *blocked* at flush join points — waiting
    /// for a running job, or compressing a still-queued job inline (always
    /// the case in `ExecMode::Sequential`, which is therefore the blocking
    /// baseline this stall is compared against). This is the residual
    /// compression stall left after the submit/join overlap.
    pub flush_stall: Duration,
    /// Compression wall time that completed off the engine's critical path:
    /// for each joined job, its compression time minus whatever the join
    /// still had to wait. Zero in `ExecMode::Sequential`; with a pool and
    /// enough idle gaps this approaches the total compression time — the
    /// overlap win `bench_throughput --compare` reports.
    pub flush_overlap_won: Duration,
    /// Per-pipeline-stage busy time accumulated across sweeps
    /// (`ExecMode::Pipelined` only; empty otherwise). Index = stage.
    pub stage_busy: Vec<Duration>,
    /// Per-pipeline-stage bubble time: wall the stage spent waiting on its
    /// upstream hand-off. `stage_bubble[0]` is always zero (stage 0 has no
    /// upstream).
    pub stage_bubble: Vec<Duration>,
    /// Decode sweeps `ExecMode::Hybrid` dispatched through the
    /// batch-chunked plane (zero in the fixed modes).
    pub hybrid_batched_sweeps: usize,
    /// Decode sweeps `ExecMode::Hybrid` dispatched through the pipelined
    /// plane.
    pub hybrid_pipelined_sweeps: usize,
    /// Plane switches the hybrid policy recorded (the first choice is not
    /// a switch; hysteresis bounds this to one per threshold crossing).
    pub hybrid_switches: usize,
    /// Tokens decoded in hybrid sweeps that ran batch-chunked.
    pub hybrid_batched_tokens: usize,
    /// Tokens decoded in hybrid sweeps that ran pipelined.
    pub hybrid_pipelined_tokens: usize,
    /// Step wall time accumulated over hybrid batch-chunked sweeps.
    pub hybrid_batched_time: Duration,
    /// Step wall time accumulated over hybrid pipelined sweeps.
    pub hybrid_pipelined_time: Duration,
    /// Aggregated trace summary, present when the engine ran with tracing
    /// enabled (see [`crate::trace::Tracer`]). Folded in at the end of
    /// `run_to_completion` and rendered by [`Self::render_text`].
    pub trace: Option<TraceSummary>,
}

impl EngineMetrics {
    /// Generated tokens per second of wall time.
    pub fn throughput(&self) -> f64 {
        self.generated_tokens as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Generated tokens per second of *decode* wall time (prefill
    /// excluded) — the decode-plane comparison metric.
    pub fn decode_throughput(&self) -> f64 {
        let secs = self.wall.saturating_sub(self.prefill).as_secs_f64();
        self.generated_tokens as f64 / secs.max(1e-9)
    }

    /// Tokens per second of the hybrid run's *batch-chunked* sweeps (step
    /// wall only). Zero when no hybrid sweep ran batch-chunked — the
    /// per-plane split behind the bench's hybrid `--compare` leg.
    pub fn hybrid_batched_throughput(&self) -> f64 {
        self.hybrid_batched_tokens as f64 / self.hybrid_batched_time.as_secs_f64().max(1e-9)
    }

    /// Tokens per second of the hybrid run's *pipelined* sweeps (step wall
    /// only). Zero when no hybrid sweep pipelined.
    pub fn hybrid_pipelined_throughput(&self) -> f64 {
        self.hybrid_pipelined_tokens as f64
            / self.hybrid_pipelined_time.as_secs_f64().max(1e-9)
    }

    /// Step-latency percentile over the recorded decode sweeps
    /// (nearest-rank on the sorted samples; `q` clamped to `[0, 1]`, with
    /// non-finite `q` treated as 1.0). Zero when no sweep decoded. The
    /// boundaries are exact: `q = 0.0` returns the minimum sample and
    /// `q = 1.0` the maximum, including for a single-sample vector.
    pub fn step_latency_pct(&self, q: f64) -> Duration {
        let n = self.step_latencies.len();
        if n == 0 {
            return Duration::ZERO;
        }
        let mut v = self.step_latencies.clone();
        v.sort_unstable();
        let q = if q.is_finite() { q.clamp(0.0, 1.0) } else { 1.0 };
        let idx = ((n - 1) as f64 * q).round() as usize;
        v[idx.min(n - 1)]
    }

    /// Median per-sweep decode step latency.
    pub fn step_p50(&self) -> Duration {
        self.step_latency_pct(0.50)
    }

    /// Tail (p99) per-sweep decode step latency.
    pub fn step_p99(&self) -> Duration {
        self.step_latency_pct(0.99)
    }

    /// Accumulate one sweep's per-stage `(busy, bubble)` pipeline timings.
    /// No-op on an empty slice, so the non-pipelined planes cost nothing.
    pub fn record_stage_times(&mut self, times: &[(Duration, Duration)]) {
        if times.is_empty() {
            return;
        }
        if self.stage_busy.len() < times.len() {
            self.stage_busy.resize(times.len(), Duration::ZERO);
            self.stage_bubble.resize(times.len(), Duration::ZERO);
        }
        for (s, &(busy, bubble)) in times.iter().enumerate() {
            self.stage_busy[s] += busy;
            self.stage_bubble[s] += bubble;
        }
    }

    /// Per-stage occupancy `busy / (busy + bubble)` in `[0, 1]` — how much
    /// of each pipeline stage's wall went to forward work rather than
    /// waiting on the upstream hand-off. Empty unless the engine ran
    /// `ExecMode::Pipelined`.
    pub fn stage_occupancy(&self) -> Vec<f64> {
        self.stage_busy
            .iter()
            .zip(&self.stage_bubble)
            .map(|(&b, &w)| {
                let total = (b + w).as_secs_f64();
                if total > 0.0 && total.is_finite() {
                    (b.as_secs_f64() / total).clamp(0.0, 1.0)
                } else {
                    // Zero (or degenerate) wall: report idle rather than
                    // NaN/Inf, which would break the CI schema diff.
                    0.0
                }
            })
            .collect()
    }

    /// Fig 3a rows: (component, seconds, fraction).
    ///
    /// Component timings accumulate across *all* threads — since PR 4,
    /// worker-side flush jobs run overlapped with the forward pass, so the
    /// accounted component time can legitimately exceed wall time. Fractions
    /// are therefore taken over `max(wall, accounted)`: they stay
    /// non-negative and sum to exactly 1 in both regimes. The residual
    /// "other (fwd)" row is clamped at zero, and any overlapped excess
    /// (`accounted − wall`, the compression that ran off the critical path)
    /// is reported as its own informational row with fraction 0 — it is a
    /// re-count of time already inside the component rows, not an extra
    /// share of the denominator.
    pub fn time_breakdown(&self) -> Vec<(String, f64, f64)> {
        let wall = self.wall.as_secs_f64();
        let accounted: f64 = ["quant", "lowrank", "sparse"]
            .iter()
            .map(|n| self.phases.get(n).as_secs_f64())
            .sum();
        let denom = wall.max(accounted).max(1e-12);
        let mut rows = Vec::new();
        for name in ["quant", "lowrank", "sparse"] {
            let secs = self.phases.get(name).as_secs_f64();
            rows.push((name.to_string(), secs, secs / denom));
        }
        let other = (wall - accounted).max(0.0);
        rows.push(("other (fwd)".to_string(), other, other / denom));
        let overlapped = (accounted - wall).max(0.0);
        rows.push(("overlapped (off critical path)".to_string(), overlapped, 0.0));
        rows
    }

    /// Plain-text snapshot for the server's `metrics` verb: one
    /// `name value` pair per line, numbers only (no units), stable names.
    /// Trace-derived lines (`trace_*`) appear only when the engine ran
    /// with tracing enabled. Every value is finite by construction — the
    /// zero-wall guards above hold even for a default (all-zero) run.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "requests_finished {}", self.requests_finished);
        let _ = writeln!(s, "requests_preempted {}", self.requests_preempted);
        let _ = writeln!(s, "requests_oom {}", self.requests_oom);
        let _ = writeln!(s, "prompt_tokens {}", self.prompt_tokens);
        let _ = writeln!(s, "generated_tokens {}", self.generated_tokens);
        let _ = writeln!(s, "max_concurrency {}", self.max_concurrency);
        let _ = writeln!(s, "peak_cache_bytes {}", self.peak_cache_bytes);
        let _ = writeln!(s, "wall_secs {:.6}", self.wall.as_secs_f64());
        let _ = writeln!(s, "prefill_secs {:.6}", self.prefill.as_secs_f64());
        let _ = writeln!(s, "prefill_chunks {}", self.prefill_chunks);
        let _ = writeln!(s, "throughput_tok_s {:.3}", self.throughput());
        let _ = writeln!(s, "decode_throughput_tok_s {:.3}", self.decode_throughput());
        let _ = writeln!(s, "step_p50_secs {:.6}", self.step_p50().as_secs_f64());
        let _ = writeln!(s, "step_p99_secs {:.6}", self.step_p99().as_secs_f64());
        let _ = writeln!(s, "flush_jobs {}", self.flush_jobs);
        let _ = writeln!(s, "flush_stall_secs {:.6}", self.flush_stall.as_secs_f64());
        let _ = writeln!(s, "flush_overlap_won_secs {:.6}", self.flush_overlap_won.as_secs_f64());
        let _ = writeln!(s, "hybrid_batched_sweeps {}", self.hybrid_batched_sweeps);
        let _ = writeln!(s, "hybrid_pipelined_sweeps {}", self.hybrid_pipelined_sweeps);
        let _ = writeln!(s, "hybrid_switches {}", self.hybrid_switches);
        let _ = writeln!(s, "hybrid_batched_tok_s {:.3}", self.hybrid_batched_throughput());
        let _ = writeln!(s, "hybrid_pipelined_tok_s {:.3}", self.hybrid_pipelined_throughput());
        for (name, secs, frac) in self.time_breakdown() {
            let key = name.split_whitespace().next().unwrap_or("other");
            let _ = writeln!(s, "breakdown_{key}_secs {secs:.6}");
            let _ = writeln!(s, "breakdown_{key}_frac {frac:.6}");
        }
        for (stage, occ) in self.stage_occupancy().iter().enumerate() {
            let _ = writeln!(s, "stage_{stage}_occupancy {occ:.6}");
        }
        if let Some(t) = &self.trace {
            let _ = writeln!(s, "trace_events {}", t.events);
            let _ = writeln!(s, "trace_logical_events {}", t.logical_events);
            let _ = writeln!(s, "trace_dropped {}", t.dropped);
            let _ = writeln!(s, "trace_quality_dropped {}", t.quality_dropped);
            let _ = writeln!(s, "trace_admitted {}", t.admitted);
            let _ = writeln!(s, "trace_preemptions {}", t.preemptions);
            let _ = writeln!(s, "trace_flushes {}", t.flushes);
            let _ = writeln!(s, "trace_finished {}", t.finished);
            let _ = writeln!(s, "trace_oom_finished {}", t.oom_finished);
            let _ = writeln!(s, "trace_quality_records {}", t.quality_records);
            let _ = writeln!(s, "trace_bytes_actual {}", t.bytes_actual);
            let _ = writeln!(s, "trace_bytes_predicted {}", t.bytes_predicted);
            let _ = writeln!(s, "trace_max_err_fro {:.6}", t.max_err_fro);
            let _ = writeln!(s, "trace_mean_err_fro {:.6}", t.mean_err_fro);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = EngineMetrics {
            generated_tokens: 100,
            wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn step_latency_percentiles() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.step_p50(), Duration::ZERO);
        assert_eq!(m.step_p99(), Duration::ZERO);
        // Unsorted on purpose: percentiles sort a copy.
        for ms in [40u64, 10, 30, 20, 50] {
            m.step_latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.step_p50(), Duration::from_millis(30));
        assert_eq!(m.step_p99(), Duration::from_millis(50));
        assert_eq!(m.step_latency_pct(0.0), Duration::from_millis(10));
        assert_eq!(m.step_latency_pct(1.0), Duration::from_millis(50));
    }

    #[test]
    fn breakdown_sums_to_one() {
        let mut m = EngineMetrics {
            wall: Duration::from_millis(100),
            ..Default::default()
        };
        m.phases.add("quant", Duration::from_millis(20));
        m.phases.add("lowrank", Duration::from_millis(10));
        let rows = m.time_breakdown();
        assert_eq!(rows.len(), 5);
        let total: f64 = rows.iter().map(|r| r.2).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!((rows[3].2 - 0.7).abs() < 1e-9, "other = {}", rows[3].2);
        assert_eq!(rows[4].1, 0.0, "no overlap when accounted < wall");
    }

    /// Overlapped flush jobs accumulate component time on worker threads,
    /// so accounted can exceed wall. Fractions must stay non-negative and
    /// sum to 1, with the excess surfaced as the overlap row.
    #[test]
    fn breakdown_overlap_exceeds_wall() {
        let mut m = EngineMetrics {
            wall: Duration::from_millis(100),
            ..Default::default()
        };
        m.phases.add("quant", Duration::from_millis(80));
        m.phases.add("lowrank", Duration::from_millis(50));
        m.phases.add("sparse", Duration::from_millis(30));
        let rows = m.time_breakdown();
        assert_eq!(rows.len(), 5);
        for (name, secs, frac) in &rows {
            assert!(*secs >= 0.0, "{name} seconds negative: {secs}");
            assert!(*frac >= 0.0, "{name} fraction negative: {frac}");
        }
        let total: f64 = rows.iter().map(|r| r.2).sum();
        assert!((total - 1.0).abs() < 1e-9, "fractions sum to {total}");
        assert_eq!(rows[3].1, 0.0, "other clamped at zero");
        // 160 ms accounted − 100 ms wall = 60 ms ran off the critical path.
        assert!((rows[4].1 - 0.060).abs() < 1e-9, "overlap = {}", rows[4].1);
        // Component fractions are over the accounted total in this regime.
        assert!((rows[0].2 - 0.5).abs() < 1e-9);
    }

    /// A run that finished before the wall clock ticked (or a default
    /// metrics value) must still render finite numbers everywhere — a
    /// NaN/Inf here silently breaks the CI bench schema diff.
    #[test]
    fn zero_wall_metrics_stay_finite() {
        let mut m = EngineMetrics::default();
        m.record_stage_times(&[(Duration::ZERO, Duration::ZERO)]);
        assert!(m.throughput().is_finite());
        assert!(m.decode_throughput().is_finite());
        for occ in m.stage_occupancy() {
            assert!(occ.is_finite(), "zero-wall occupancy must be finite, got {occ}");
            assert_eq!(occ, 0.0);
        }
        for (name, secs, frac) in m.time_breakdown() {
            assert!(secs.is_finite(), "{name} seconds not finite");
            assert!(frac.is_finite(), "{name} fraction not finite");
        }
        let text = m.render_text();
        for line in text.lines() {
            let val = line.rsplit(' ').next().unwrap();
            assert!(
                val.parse::<f64>().map(f64::is_finite).unwrap_or(false),
                "non-finite metrics line: {line}"
            );
        }
    }

    /// Quantile boundaries must be exact: q = 0 is the minimum, q = 1 the
    /// maximum, a single-sample vector returns its sample for every q, and
    /// pathological q (NaN, ±Inf, out of range) must not panic or index
    /// out of bounds.
    #[test]
    fn quantile_boundaries_and_single_sample() {
        let mut m = EngineMetrics::default();
        m.step_latencies.push(Duration::from_millis(7));
        for q in [0.0, 0.5, 1.0, -3.0, 42.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(m.step_latency_pct(q), Duration::from_millis(7), "q = {q}");
        }
        m.step_latencies.push(Duration::from_millis(1));
        m.step_latencies.push(Duration::from_millis(99));
        assert_eq!(m.step_latency_pct(0.0), Duration::from_millis(1));
        assert_eq!(m.step_latency_pct(1.0), Duration::from_millis(99));
        assert_eq!(m.step_latency_pct(-1.0), Duration::from_millis(1), "q clamps low");
        assert_eq!(m.step_latency_pct(2.0), Duration::from_millis(99), "q clamps high");
        assert_eq!(m.step_latency_pct(f64::NAN), Duration::from_millis(99), "NaN acts as 1.0");
    }

    #[test]
    fn stage_times_accumulate_and_occupancy() {
        let mut m = EngineMetrics::default();
        m.record_stage_times(&[]);
        assert!(m.stage_busy.is_empty(), "empty slice is a no-op");
        let sweep = [
            (Duration::from_millis(30), Duration::ZERO),
            (Duration::from_millis(10), Duration::from_millis(30)),
        ];
        m.record_stage_times(&sweep);
        m.record_stage_times(&sweep);
        assert_eq!(m.stage_busy, vec![Duration::from_millis(60), Duration::from_millis(20)]);
        assert_eq!(m.stage_bubble, vec![Duration::ZERO, Duration::from_millis(60)]);
        let occ = m.stage_occupancy();
        assert!((occ[0] - 1.0).abs() < 1e-9);
        assert!((occ[1] - 0.25).abs() < 1e-9);
    }
}
