//! Analytic KV-size model (the paper's "KV size %" columns and Fig 6's
//! component breakdown).
//!
//! [`CompressedMatrix::nbytes`] measures what we actually stored; this module
//! *predicts* sizes from configuration alone, so benches can sweep
//! sequence-length/bit/rank grids (Table 9) without materializing tensors,
//! and so the cache manager can plan admission against a byte budget before
//! compressing anything.

use super::compose::{Backbone, GearConfig, Method};
use super::KvKind;

/// Size breakdown of one compressed n×d KV matrix, in bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SizeBreakdown {
    /// Packed quantized codes.
    pub quant_bytes: usize,
    /// FP16 scales + zero-points.
    pub meta_bytes: usize,
    /// Sparse outliers: FP16 values + u32 index pairs.
    pub sparse_bytes: usize,
    /// FP16 low-rank factors.
    pub lowrank_bytes: usize,
    /// FP16 dense storage (FP16 method / streaming buffer tokens).
    pub dense_bytes: usize,
}

impl SizeBreakdown {
    pub fn total(&self) -> usize {
        self.quant_bytes
            + self.meta_bytes
            + self.sparse_bytes
            + self.lowrank_bytes
            + self.dense_bytes
    }

    /// Fraction of the FP16 size of an n×d matrix.
    pub fn frac_of_fp16(&self, n: usize, d: usize) -> f64 {
        self.total() as f64 / (n * d * 2) as f64
    }

    pub fn add(&self, other: &SizeBreakdown) -> SizeBreakdown {
        SizeBreakdown {
            quant_bytes: self.quant_bytes + other.quant_bytes,
            meta_bytes: self.meta_bytes + other.meta_bytes,
            sparse_bytes: self.sparse_bytes + other.sparse_bytes,
            lowrank_bytes: self.lowrank_bytes + other.lowrank_bytes,
            dense_bytes: self.dense_bytes + other.dense_bytes,
        }
    }
}

/// Number of scale/zero groups for a backbone over an n-tokens × d-channels
/// matrix. `is_key`: per-channel grouping (axis = tokens) vs per-token.
pub fn n_groups(backbone: Backbone, is_key: bool, n: usize, d: usize) -> usize {
    match backbone {
        Backbone::PerTokenGroup(g) => n * d.div_ceil(g.min(d).max(1)),
        Backbone::Kcvt => {
            if is_key {
                d // one group per channel
            } else {
                n // one group per token
            }
        }
        Backbone::Kivi(g) => {
            if is_key {
                d * n.div_ceil(g.min(n).max(1))
            } else {
                n * d.div_ceil(g.min(d).max(1))
            }
        }
    }
}

/// Predicted size of one n×d KV matrix compressed under `method`.
///
/// `is_key` selects the grouping axis; `n_heads` shapes the low-rank factors
/// (`Σ_h (n + d_H) · r` FP16 entries).
pub fn predict(method: Method, is_key: bool, n: usize, d: usize, n_heads: usize) -> SizeBreakdown {
    let mut b = SizeBreakdown::default();
    if n == 0 || d == 0 {
        return b;
    }
    let quant = |bits: u8| (n * d * bits as usize).div_ceil(8);
    let meta = |backbone: Backbone| n_groups(backbone, is_key, n, d) * 4; // scale+zero, 2 B each
    let sparse = |s: f64| {
        let vec_len = if is_key { n } else { d };
        let n_vecs = if is_key { d } else { n };
        let k = super::outlier::k_per_side(vec_len, s);
        // FP16 value + u16 within-vector index per entry, u32 offsets per vector.
        n_vecs * 2 * k * (2 + 2) + (n_vecs + 1) * 4
    };
    let lowrank = |r: usize| {
        let dh = d / n_heads.max(1);
        n_heads * (n * r.min(n).min(dh).max(1) + dh * r.min(n).min(dh).max(1)) * 2
    };

    match method {
        Method::Fp16 => b.dense_bytes = n * d * 2,
        Method::QuantOnly { bits, backbone } => {
            b.quant_bytes = quant(bits);
            b.meta_bytes = meta(backbone);
        }
        Method::OutlierAware { bits, backbone, s } => {
            b.quant_bytes = quant(bits);
            b.meta_bytes = meta(backbone);
            b.sparse_bytes = sparse(s);
        }
        Method::GearL { bits, backbone, r } => {
            b.quant_bytes = quant(bits);
            b.meta_bytes = meta(backbone);
            b.lowrank_bytes = lowrank(r);
        }
        Method::Gear { bits, backbone, s, r } => {
            b.quant_bytes = quant(bits);
            b.meta_bytes = meta(backbone);
            b.sparse_bytes = sparse(s);
            b.lowrank_bytes = lowrank(r);
        }
        Method::LowRankOnly { r } => b.lowrank_bytes = lowrank(r),
        Method::SparseOnly { s } => b.sparse_bytes = sparse(s),
    }
    b
}

/// Predicted bytes of one n×d matrix compressed under `cfg` — the
/// baseline the trace quality probe records next to achieved bytes
/// (`predict` is exact by the `predict_matches_measured` contract, so
/// any achieved/predicted gap in a trace is a real accounting bug).
pub fn predicted_nbytes(cfg: &GearConfig, kind: KvKind, n: usize, d: usize) -> usize {
    predict(cfg.method, matches!(kind, KvKind::Key), n, d, cfg.n_heads).total()
}

/// Predicted KV-size fraction for a full cache: K and V matrices of
/// `n_layers` layers, each n×d, plus `buffer_tokens` FP16 tokens in the
/// streaming buffer (counted for both K and V).
pub fn predict_cache_frac(
    method: Method,
    n: usize,
    d: usize,
    n_layers: usize,
    n_heads: usize,
    buffer_tokens: usize,
) -> f64 {
    if n == 0 {
        return 1.0;
    }
    let per_layer = predict(method, true, n, d, n_heads)
        .add(&predict(method, false, n, d, n_heads));
    let buffer = 2 * buffer_tokens.min(n) * d * 2; // K + V rows at FP16
    let total = n_layers * (per_layer.total() + buffer);
    let fp16 = n_layers * 2 * n * d * 2;
    total as f64 / fp16 as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gear::compose::{compress, GearConfig};
    use crate::gear::KvKind;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn predict_matches_measured() {
        // The analytic model must agree with actually-stored bytes.
        let mut rng = Rng::new(60);
        let x = Tensor::randn(&[128, 64], &mut rng, 1.0);
        for (m, kind, is_key) in [
            (Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(32) }, KvKind::Key, true),
            (Method::QuantOnly { bits: 4, backbone: Backbone::Kcvt }, KvKind::Value, false),
            (Method::gear_default(2), KvKind::Key, true),
            (Method::gear_l_default(4), KvKind::Value, false),
            (Method::Fp16, KvKind::Key, true),
            (Method::SparseOnly { s: 0.04 }, KvKind::Value, false),
        ] {
            let c = compress(&x, kind, &GearConfig::new(m, 4));
            let p = predict(m, is_key, 128, 64, 4);
            assert_eq!(c.nbytes(), p.total(), "{m:?}");
        }
    }

    #[test]
    fn fp16_fraction_is_one() {
        let p = predict(Method::Fp16, true, 100, 64, 4);
        assert!((p.frac_of_fp16(100, 64) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn two_bit_quant_fraction_near_eighth() {
        // 2 bit / 16 bit = 12.5% + metadata.
        let p = predict(
            Method::QuantOnly { bits: 2, backbone: Backbone::Kcvt },
            true,
            1024,
            128,
            4,
        );
        let f = p.frac_of_fp16(1024, 128);
        assert!(f > 0.125 && f < 0.14, "{f}");
    }

    #[test]
    fn paper_ordering_of_method_sizes() {
        // Table 1's Ave. KV size ordering at 2-bit:
        // per-token/KIVI (21.7%) < GEAR-L (23.6%) < GEAR (27.6%).
        let (n, d) = (1024, 128);
        let kivi_m = Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) };
        let kivi = predict(kivi_m, true, n, d, 4).frac_of_fp16(n, d);
        let gearl = predict(Method::gear_l_default(2), true, n, d, 4).frac_of_fp16(n, d);
        let gear = predict(Method::gear_default(2), true, n, d, 4).frac_of_fp16(n, d);
        assert!(kivi < gearl && gearl < gear, "{kivi} {gearl} {gear}");
        // And magnitudes are in the paper's ballpark (< 35%).
        assert!(gear < 0.35, "{gear}");
    }

    #[test]
    fn cache_frac_includes_buffer() {
        let m = Method::gear_default(2);
        let without = predict_cache_frac(m, 1024, 128, 4, 4, 0);
        let with = predict_cache_frac(m, 1024, 128, 4, 4, 64);
        assert!(with > without);
        assert!(with - without < 0.15);
    }

    #[test]
    fn zero_tokens_degenerate() {
        assert_eq!(predict(Method::gear_default(2), true, 0, 64, 4).total(), 0);
        assert_eq!(predict_cache_frac(Method::Fp16, 0, 64, 4, 4, 0), 1.0);
    }
}
