//! Outlier extraction: the sparse component `S = Filter_s(X)` (Eq. 4).
//!
//! For each vector along the grouping axis (channel vectors for Keys, token
//! vectors for Values) the top `s/2 %` and bottom `s/2 %` entries by value
//! are moved into a sparse COO matrix stored in full precision; the dense
//! remainder `X − S` is what gets quantized. Selection uses
//! `select_nth_unstable` (average O(n)) rather than a sort.

use crate::tensor::Tensor;
use crate::util::f16::to_f16_precision;

use super::quant::Axis;

/// Sparse matrix in coordinate format. Values are FP16-rounded (the paper
/// stores outliers in full precision = FP16 in its setting).
///
/// In-memory we keep (row, col) u32 pairs for fast row scans; the *stored*
/// layout this accounts for is the paper's compressed-sparse form along the
/// filter axis: one u32 offset per vector + a u16 within-vector index and an
/// FP16 value per entry (4 B/entry + 4 B/vector) — the "two index vectors
/// and one value vector" the paper describes.
#[derive(Debug, Clone)]
pub struct SparseCoo {
    pub rows: usize,
    pub cols: usize,
    /// Axis the outliers were filtered along (determines the CSR direction).
    pub axis: Axis,
    /// (row, col) coordinates, sorted row-major.
    pub idx: Vec<(u32, u32)>,
    /// FP16-rounded values, parallel to `idx`.
    pub val: Vec<f32>,
}

impl Default for SparseCoo {
    fn default() -> Self {
        SparseCoo { rows: 0, cols: 0, axis: Axis::Row, idx: Vec::new(), val: Vec::new() }
    }
}

impl SparseCoo {
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Add `S` into a dense row-major buffer.
    pub fn add_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.rows * self.cols);
        for (k, &(i, j)) in self.idx.iter().enumerate() {
            out[i as usize * self.cols + j as usize] += self.val[k];
        }
    }

    /// Add the entries of row `i` into a cols-long buffer. COO is sorted
    /// row-major, so this is a binary search + linear scan.
    pub fn add_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        let start = self.idx.partition_point(|&(r, _)| (r as usize) < i);
        for k in start..self.idx.len() {
            let (r, c) = self.idx[k];
            if r as usize != i {
                break;
            }
            out[c as usize] += self.val[k];
        }
    }

    pub fn to_dense(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.rows, self.cols]);
        self.add_into(t.data_mut());
        t
    }

    /// Real storage bytes in the compressed-sparse layout along the filter
    /// axis: per entry an FP16 value + u16 within-vector index, plus one u32
    /// offset per vector (and one terminator).
    pub fn nbytes(&self) -> usize {
        let n_vecs = match self.axis {
            Axis::Row => self.rows,
            Axis::Col => self.cols,
        };
        self.val.len() * 2 + self.idx.len() * 2 + (n_vecs + 1) * 4
    }
}

/// Number of entries extracted from *each side* (top and bottom) of a
/// vector of length `len` at sparsity fraction `s` (e.g. 0.02 for the
/// paper's s = 2 %).
pub fn k_per_side(len: usize, s: f64) -> usize {
    ((len as f64 * s) / 2.0).round() as usize
}

/// Extract outliers from `x` per-vector along `axis`.
///
/// Returns `(S, X − S)`: the sparse outlier matrix and the dense remainder
/// with extracted positions zeroed (so quantization sees small-magnitude
/// entries only).
///
/// This is the sparse term `S = Filter_s(X)` of Eq. (4)'s
/// `X ≈ D̂ + L + S`: the entries quantization handles worst — the extreme
/// magnitudes that would stretch every group's range — kept exactly (at
/// FP16) instead:
///
/// ```
/// use gear_serve::gear::outlier::filter_outliers;
/// use gear_serve::gear::quant::Axis;
/// use gear_serve::tensor::Tensor;
/// use gear_serve::util::rng::Rng;
///
/// // Plant one huge positive and one huge negative entry per token row.
/// let mut x = Tensor::randn(&[8, 64], &mut Rng::new(23), 0.1);
/// for i in 0..8 {
///     x.row_mut(i)[3] = 100.0;
///     x.row_mut(i)[40] = -100.0;
/// }
///
/// let (s, remainder) = filter_outliers(&x, 0.04, Axis::Row); // k = 1/side
/// assert_eq!(s.nnz(), 8 * 2); // exactly the planted extremes
/// // The dense remainder X − S is what the backbone quantizes: with the
/// // extremes gone its per-group range collapses.
/// assert!(remainder.data().iter().all(|v| v.abs() < 1.0));
/// // X is recovered exactly, up to FP16 rounding of the outlier values.
/// let mut recon = remainder.clone();
/// s.add_into(recon.data_mut());
/// for (a, b) in x.data().iter().zip(recon.data()) {
///     assert!((a - b).abs() <= a.abs() * 5e-4 + 1e-6);
/// }
/// ```
pub fn filter_outliers(x: &Tensor, s: f64, axis: Axis) -> (SparseCoo, Tensor) {
    let (rows, cols) = (x.rows(), x.cols());
    let mut remainder = x.clone();
    let mut entries: Vec<(u32, u32, f32)> = Vec::new();

    let (n_vecs, vec_len) = match axis {
        Axis::Row => (rows, cols),
        Axis::Col => (cols, rows),
    };
    let k = k_per_side(vec_len, s);
    if k == 0 || s <= 0.0 {
        return (SparseCoo { rows, cols, axis, ..Default::default() }, remainder);
    }

    // Element accessor for vector v, position p.
    let coord = |v: usize, p: usize| -> (usize, usize) {
        match axis {
            Axis::Row => (v, p),
            Axis::Col => (p, v),
        }
    };

    let mut scratch: Vec<(f32, u32)> = Vec::with_capacity(vec_len);
    for v in 0..n_vecs {
        scratch.clear();
        for p in 0..vec_len {
            let (i, j) = coord(v, p);
            scratch.push((x.data()[i * cols + j], p as u32));
        }
        // Bottom k: k-th smallest partition.
        scratch.select_nth_unstable_by(k - 1, |a, b| a.0.total_cmp(&b.0));
        let bottom: Vec<u32> = scratch[..k].iter().map(|&(_, p)| p).collect();
        // Top k among the rest (indices >= k after the partition).
        let rest = &mut scratch[k..];
        let rlen = rest.len();
        if rlen > k {
            rest.select_nth_unstable_by(rlen - k, |a, b| a.0.total_cmp(&b.0));
        }
        let top: Vec<u32> = rest[rlen.saturating_sub(k)..].iter().map(|&(_, p)| p).collect();

        for p in bottom.into_iter().chain(top) {
            let (i, j) = coord(v, p as usize);
            let val = remainder.data()[i * cols + j];
            entries.push((i as u32, j as u32, to_f16_precision(val)));
            remainder.data_mut()[i * cols + j] = 0.0;
        }
    }

    entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
    let idx = entries.iter().map(|&(i, j, _)| (i, j)).collect();
    let val = entries.iter().map(|&(_, _, v)| v).collect();
    (SparseCoo { rows, cols, axis, idx, val }, remainder)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn extracts_expected_count() {
        let mut r = Rng::new(20);
        let x = Tensor::randn(&[100, 64], &mut r, 1.0);
        let (s, _) = filter_outliers(&x, 0.02, Axis::Row);
        // per row: k_per_side(64, 0.02) = round(0.64) = 1 per side -> 2 per row
        assert_eq!(s.nnz(), 100 * 2);
        let (s2, _) = filter_outliers(&x, 0.02, Axis::Col);
        // per column: k_per_side(100, 0.02) = 1 -> 2 per column
        assert_eq!(s2.nnz(), 64 * 2);
    }

    #[test]
    fn zero_sparsity_is_noop() {
        let mut r = Rng::new(21);
        let x = Tensor::randn(&[10, 10], &mut r, 1.0);
        let (s, rem) = filter_outliers(&x, 0.0, Axis::Row);
        assert_eq!(s.nnz(), 0);
        assert_eq!(rem, x);
    }

    #[test]
    fn reconstruction_is_exact_up_to_f16() {
        let mut r = Rng::new(22);
        let x = Tensor::randn(&[50, 32], &mut r, 2.0);
        let (s, rem) = filter_outliers(&x, 0.1, Axis::Row);
        let mut recon = rem.clone();
        s.add_into(recon.data_mut());
        for (a, b) in x.data().iter().zip(recon.data()) {
            let tol = a.abs() * 5e-4 + 1e-6; // fp16 rounding of outlier values
            assert!((a - b).abs() <= tol, "|{a}-{b}| > {tol}");
        }
    }

    #[test]
    fn extracts_true_extremes() {
        // Plant one huge positive and one huge negative entry per row.
        let mut r = Rng::new(23);
        let mut x = Tensor::randn(&[8, 64], &mut r, 0.1);
        for i in 0..8 {
            x.row_mut(i)[3] = 100.0;
            x.row_mut(i)[40] = -100.0;
        }
        let (s, rem) = filter_outliers(&x, 0.04, Axis::Row); // k=1 per side
        assert_eq!(s.nnz(), 16);
        for i in 0..8 {
            assert_eq!(rem.row(i)[3], 0.0);
            assert_eq!(rem.row(i)[40], 0.0);
        }
        // Remainder has tight range now.
        for v in rem.data() {
            assert!(v.abs() < 1.0);
        }
    }

    #[test]
    fn row_lookup_matches_dense() {
        let mut r = Rng::new(24);
        let x = Tensor::randn(&[30, 16], &mut r, 1.0);
        let (s, _) = filter_outliers(&x, 0.2, Axis::Col);
        let dense = s.to_dense();
        let mut row = vec![0.0f32; 16];
        for i in 0..30 {
            row.fill(0.0);
            s.add_row_into(i, &mut row);
            assert_eq!(&row[..], dense.row(i), "row {i}");
        }
    }

    #[test]
    fn prop_remainder_has_no_entry_beyond_kept_range() {
        prop::check(
            |r| {
                let (rows, cols) = prop::gen_shape(r, 40, 40);
                Tensor::new(&[rows, cols], prop::gen_kv_like(r, rows * cols))
            },
            |x| {
                let (s, rem) = filter_outliers(x, 0.1, Axis::Row);
                let k = k_per_side(x.cols(), 0.1);
                if k == 0 {
                    return Ok(());
                }
                // For every row: every remaining |entry| must lie within the
                // [min_kept, max_kept] envelope of that row's kept values.
                for i in 0..x.rows() {
                    let extracted: Vec<f32> = s
                        .idx
                        .iter()
                        .zip(&s.val)
                        .filter(|(&(r_, _), _)| r_ as usize == i)
                        .map(|(_, &v)| v)
                        .collect();
                    prop_assert!(
                        extracted.len() == 2 * k,
                        "row {i}: {} != {}",
                        extracted.len(),
                        2 * k
                    );
                    let max_pos = extracted.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let min_neg = extracted.iter().cloned().fold(f32::INFINITY, f32::min);
                    for (j, &v) in rem.row(i).iter().enumerate() {
                        if s.idx.binary_search(&(i as u32, j as u32)).is_ok() {
                            continue; // zeroed position
                        }
                        prop_assert!(
                            v <= max_pos + 1e-3 && v >= min_neg - 1e-3,
                            "row {i} col {j}: {v} outside [{min_neg}, {max_pos}]"
                        );
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nbytes_accounting() {
        let s = SparseCoo {
            rows: 4,
            cols: 4,
            axis: Axis::Row,
            idx: vec![(0, 0), (1, 1)],
            val: vec![1.0, 2.0],
        };
        // 2 entries * (2B f16 + 2B u16) + (4 rows + 1) * 4B offsets.
        assert_eq!(s.nbytes(), 2 * 4 + 5 * 4);
    }
}
