//! Approximation-error metrics and singular-spectrum analysis.
//!
//! Backs the paper's Figure 1a (method error comparison), Figure 2a
//! (single-technique error curves) and Figure 2b (residual spectrum decay).
//! The exact singular values come from a cyclic Jacobi eigensolver on the
//! Gram matrix — only used offline for analysis/tests, never on the serving
//! path.

use crate::tensor::ops::{fro_dist, fro_norm};

/// Relative Frobenius approximation error ‖X − X̂‖_F / ‖X‖_F.
pub fn rel_error(x: &[f32], xhat: &[f32]) -> f64 {
    let norm = fro_norm(x);
    if norm == 0.0 {
        return if fro_norm(xhat) == 0.0 { 0.0 } else { f64::INFINITY };
    }
    fro_dist(x, xhat) / norm
}

/// Exact singular values of a row-major n×d matrix, descending.
///
/// Computes the eigenvalues of the smaller Gram matrix (XᵀX or XXᵀ) with
/// cyclic Jacobi rotations, then takes square roots. O(m³) for m = min(n,d);
/// fine for head-sized blocks (d_H ≤ 128).
pub fn singular_values(x: &[f32], n: usize, d: usize) -> Vec<f64> {
    assert_eq!(x.len(), n * d);
    let m = n.min(d);
    // Build the m×m Gram matrix in f64.
    let mut g = vec![0.0f64; m * m];
    if d <= n {
        // G = XᵀX (d×d)
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            for a in 0..d {
                let ra = row[a] as f64;
                if ra == 0.0 {
                    continue;
                }
                for b in a..d {
                    g[a * m + b] += ra * row[b] as f64;
                }
            }
        }
    } else {
        // G = XXᵀ (n×n)
        for a in 0..n {
            let ra = &x[a * d..(a + 1) * d];
            for b in a..n {
                let rb = &x[b * d..(b + 1) * d];
                let mut s = 0.0f64;
                for k in 0..d {
                    s += ra[k] as f64 * rb[k] as f64;
                }
                g[a * m + b] = s;
            }
        }
    }
    // Mirror lower triangle.
    for a in 0..m {
        for b in 0..a {
            g[a * m + b] = g[b * m + a];
        }
    }

    let mut evs = jacobi_eigenvalues(&mut g, m);
    evs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    evs.into_iter().map(|ev| ev.max(0.0).sqrt()).collect()
}

/// Eigenvalues of a symmetric m×m matrix (row-major, modified in place) via
/// cyclic Jacobi rotations. Unsorted.
pub fn jacobi_eigenvalues(a: &mut [f64], m: usize) -> Vec<f64> {
    assert_eq!(a.len(), m * m);
    if m == 1 {
        return vec![a[0]];
    }
    const MAX_SWEEPS: usize = 50;
    for _ in 0..MAX_SWEEPS {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0f64;
        for p in 0..m {
            for q in (p + 1)..m {
                off += a[p * m + q] * a[p * m + q];
            }
        }
        let scale: f64 = (0..m).map(|i| a[i * m + i].abs()).sum::<f64>().max(1e-300);
        if off.sqrt() <= 1e-14 * scale {
            break;
        }
        for p in 0..m {
            for q in (p + 1)..m {
                let apq = a[p * m + q];
                if apq == 0.0 {
                    continue;
                }
                let app = a[p * m + p];
                let aqq = a[q * m + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ) on both sides.
                for k in 0..m {
                    let akp = a[k * m + p];
                    let akq = a[k * m + q];
                    a[k * m + p] = c * akp - s * akq;
                    a[k * m + q] = s * akp + c * akq;
                }
                for k in 0..m {
                    let apk = a[p * m + k];
                    let aqk = a[q * m + k];
                    a[p * m + k] = c * apk - s * aqk;
                    a[q * m + k] = s * apk + c * aqk;
                }
            }
        }
    }
    (0..m).map(|i| a[i * m + i]).collect()
}

/// Spectrum summary used by the Fig 2b reproduction: fraction of spectral
/// energy (Σσᵢ²) captured by the top-k singular values.
pub fn energy_captured(svals: &[f64], k: usize) -> f64 {
    let total: f64 = svals.iter().map(|s| s * s).sum();
    if total == 0.0 {
        return 1.0;
    }
    svals.iter().take(k).map(|s| s * s).sum::<f64>() / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_into;
    use crate::util::rng::Rng;

    #[test]
    fn rel_error_basics() {
        let x = [1.0f32, 2.0, 3.0];
        assert_eq!(rel_error(&x, &x), 0.0);
        let zero = [0.0f32; 3];
        assert_eq!(rel_error(&zero, &zero), 0.0);
        assert!(rel_error(&zero, &x).is_infinite());
    }

    #[test]
    fn jacobi_diagonal_matrix() {
        let mut a = vec![0.0f64; 9];
        a[0] = 3.0;
        a[4] = 1.0;
        a[8] = 2.0;
        let mut evs = jacobi_eigenvalues(&mut a, 3);
        evs.sort_by(|x, y| y.partial_cmp(x).unwrap());
        assert!((evs[0] - 3.0).abs() < 1e-12);
        assert!((evs[1] - 2.0).abs() < 1e-12);
        assert!((evs[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3 and 1.
        let mut a = vec![2.0, 1.0, 1.0, 2.0];
        let mut evs = jacobi_eigenvalues(&mut a, 2);
        evs.sort_by(|x, y| y.partial_cmp(x).unwrap());
        assert!((evs[0] - 3.0).abs() < 1e-12);
        assert!((evs[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_values_of_orthogonal_rows() {
        // X = [[2,0,0],[0,3,0]] -> σ = {3, 2}.
        let x = [2.0f32, 0.0, 0.0, 0.0, 3.0, 0.0];
        let sv = singular_values(&x, 2, 3);
        assert_eq!(sv.len(), 2);
        assert!((sv[0] - 3.0).abs() < 1e-6);
        assert!((sv[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn spectrum_matches_frobenius() {
        // Σσ² must equal ‖X‖²_F.
        let mut r = Rng::new(40);
        for (n, d) in [(10, 6), (6, 10), (8, 8)] {
            let mut x = vec![0.0f32; n * d];
            r.fill_normal(&mut x, 0.0, 1.0);
            let sv = singular_values(&x, n, d);
            let energy: f64 = sv.iter().map(|s| s * s).sum();
            let fro2 = fro_norm(&x).powi(2);
            assert!(
                (energy - fro2).abs() / fro2 < 1e-6,
                "{n}x{d}: Σσ²={energy} vs ‖X‖²={fro2}"
            );
        }
    }

    #[test]
    fn rank_deficient_spectrum() {
        // Rank-2 matrix: singular values beyond 2 are ~0.
        let mut r = Rng::new(41);
        let (n, d, k) = (12, 9, 2);
        let mut u = vec![0.0f32; n * k];
        let mut v = vec![0.0f32; k * d];
        r.fill_normal(&mut u, 0.0, 1.0);
        r.fill_normal(&mut v, 0.0, 1.0);
        let mut x = vec![0.0f32; n * d];
        matmul_into(&u, &v, n, k, d, &mut x);
        let sv = singular_values(&x, n, d);
        assert!(sv[1] > 1e-3);
        for s in &sv[2..] {
            assert!(*s < sv[0] * 1e-4, "trailing σ {s}");
        }
        assert!(energy_captured(&sv, 2) > 0.999);
    }
}
