//! GEAR: the paper's core contribution.
//!
//! A KV matrix `X` (tokens × channels) is approximated as
//!
//! ```text
//! X  ≈  D̂  +  L  +  S
//! ```
//!
//! * [`quant`] — `D̂ = Quant_b(X − S)`: uniform asymmetric quantization of the
//!   outlier-free backbone at 2/4/8 bits, with all the grouping schemes the
//!   paper evaluates (per-token group-wise / KIVI / KCVT).
//! * [`outlier`] — `S = Filter_s(X)`: per-vector top/bottom `s/2 %` outliers
//!   kept in full precision as a sparse COO matrix.
//! * [`lowrank`] — `L = concat_h(A_h B_hᵀ)`: head-wise rank-`r` approximation
//!   of the residual `R = X − D̂ − S`, via the power-iteration solver
//!   (Algorithm 2 of the paper).
//! * [`compose`] — the full GEAR / GEAR-L / outlier-aware pipelines and the
//!   compressed-matrix type the KV cache stores.
//! * [`error`] — approximation-error and singular-spectrum utilities
//!   (Figures 1a / 2a / 2b).
//! * [`size`] — exact byte accounting for every component (KV-size % metric).

pub mod adaptive;
pub mod attend;
pub mod compose;
pub mod error;
pub mod lowrank;
pub mod outlier;
pub mod quant;
pub mod size;

pub use compose::{CompressedMatrix, GearConfig, Method};
pub use quant::{Axis, GroupSize, QuantScheme, QuantizedMatrix};

use std::cell::RefCell;
use std::time::Duration;

thread_local! {
    /// Per-thread accumulator attributing wall time to GEAR components
    /// (quant / sparse / lowrank). Feeds the Fig 3a time-breakdown
    /// reproduction without plumbing a timer through every call.
    static PHASE_TIMER: RefCell<crate::util::timing::PhaseTimer> =
        RefCell::new(crate::util::timing::PhaseTimer::new());
}

/// Record `d` against `phase` in the thread-local GEAR timer.
pub(crate) fn record_phase(phase: &str, d: Duration) {
    PHASE_TIMER.with(|t| t.borrow_mut().add(phase, d));
}

/// Time `f`, attributing it to `phase`.
pub(crate) fn timed_phase<T>(phase: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    record_phase(phase, t0.elapsed());
    out
}

/// Take (and reset) the accumulated component timings for this thread.
pub fn take_phase_timings() -> crate::util::timing::PhaseTimer {
    PHASE_TIMER.with(|t| std::mem::take(&mut *t.borrow_mut()))
}

/// Merge externally-collected component timings into this thread's
/// accumulator. The batch executor's worker threads each accumulate into
/// their own thread-local; the engine folds them back through this so the
/// Fig 3a breakdown still covers work done off the engine thread.
pub fn merge_phase_timings(other: &crate::util::timing::PhaseTimer) {
    PHASE_TIMER.with(|t| t.borrow_mut().merge(other));
}

/// Whether a matrix is a Key or Value cache. Keys are quantized / filtered
/// per-channel (column vectors), Values per-token (row vectors), following
/// KIVI / KVQuant's observation that Key outliers live in fixed channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvKind {
    Key,
    Value,
}

impl KvKind {
    /// The grouping axis this kind quantizes along.
    pub fn axis(self) -> Axis {
        match self {
            KvKind::Key => Axis::Col,
            KvKind::Value => Axis::Row,
        }
    }
}
