//! The GEAR composite pipeline (§3 of the paper) and the baselines it is
//! compared against.
//!
//! `compress` produces a [`CompressedMatrix`] holding any subset of the
//! three components: quantized backbone `D̂`, sparse outliers `S`, head-wise
//! low-rank residual `L`. Reconstruction is `D̂ + L + S`; storage is the sum
//! of real component bytes.

use crate::tensor::Tensor;
use crate::util::f16::to_f16_precision;
use crate::util::rng::Rng;

use super::lowrank::HeadwiseLowRank;
use super::outlier::{filter_outliers, SparseCoo};
use super::quant::{QuantScheme, QuantizedMatrix};
use super::KvKind;

/// Quantization backbone scheme (the paper's superscripts: `(KCVT)`,
/// `(KIVI, g=64)`, per-token).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backbone {
    /// FlexGen-style per-token group-wise quantization.
    PerTokenGroup(usize),
    /// Per-channel Key / per-token Value, whole-vector groups (the paper's
    /// lite backbone).
    Kcvt,
    /// Per-channel Key / per-token Value with fine-grained groups of `g`.
    Kivi(usize),
}

impl Backbone {
    pub fn scheme(self, kind: KvKind) -> QuantScheme {
        match self {
            Backbone::PerTokenGroup(g) => QuantScheme::per_token_group(g),
            Backbone::Kcvt => QuantScheme::kcvt(kind),
            Backbone::Kivi(g) => QuantScheme::kivi(kind, g),
        }
    }

    pub fn label(self) -> String {
        match self {
            Backbone::PerTokenGroup(g) => format!("per-token g={g}"),
            Backbone::Kcvt => "KCVT".to_string(),
            Backbone::Kivi(g) => format!("KIVI g={g}"),
        }
    }
}

/// A compression method from the paper's evaluation matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Uncompressed FP16 baseline.
    Fp16,
    /// Backbone quantization only.
    QuantOnly { bits: u8, backbone: Backbone },
    /// Quantization + sparse outliers (Table 8's "Outlier-A.").
    OutlierAware { bits: u8, backbone: Backbone, s: f64 },
    /// Quantization + low-rank error reduction (GEAR-L).
    GearL { bits: u8, backbone: Backbone, r: usize },
    /// Full GEAR: quantization + sparse + low-rank.
    Gear { bits: u8, backbone: Backbone, s: f64, r: usize },
    /// Low-rank approximation alone (Fig 2a single-technique curve).
    LowRankOnly { r: usize },
    /// Outlier extraction alone (Fig 2a single-technique curve).
    SparseOnly { s: f64 },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::QuantOnly { bits, backbone } => format!("{} {bits}-bit", backbone.label()),
            Method::OutlierAware { bits, backbone, s } => {
                format!("Outlier-A.(s={:.0}%) {} {bits}-bit", s * 100.0, backbone.label())
            }
            Method::GearL { bits, backbone, r } => {
                format!("GEAR-L(r={r}) {} {bits}-bit", backbone.label())
            }
            Method::Gear { bits, backbone, s, r } => {
                format!("GEAR(s={:.0}%,r={r}) {} {bits}-bit", s * 100.0, backbone.label())
            }
            Method::LowRankOnly { r } => format!("LowRank-only r={r}"),
            Method::SparseOnly { s } => format!("Sparse-only s={:.0}%", s * 100.0),
        }
    }

    pub fn is_fp16(&self) -> bool {
        matches!(self, Method::Fp16)
    }

    /// The paper's standard GEAR configuration for a bit width.
    pub fn gear_default(bits: u8) -> Method {
        match bits {
            4 => Method::Gear { bits: 4, backbone: Backbone::Kcvt, s: 0.02, r: 4 },
            _ => Method::Gear { bits, backbone: Backbone::Kivi(64), s: 0.02, r: 4 },
        }
    }

    /// The paper's standard GEAR-L configuration for a bit width.
    pub fn gear_l_default(bits: u8) -> Method {
        match bits {
            4 => Method::GearL { bits: 4, backbone: Backbone::Kcvt, r: 4 },
            _ => Method::GearL { bits, backbone: Backbone::Kivi(64), r: 4 },
        }
    }
}

/// Parameters shared by compression calls that `Method` does not carry.
#[derive(Debug, Clone, Copy)]
pub struct GearConfig {
    pub method: Method,
    /// Heads for head-wise low-rank decomposition. Must divide the channel
    /// count of the matrices being compressed.
    pub n_heads: usize,
    /// Power-iteration sweeps (paper Algorithm 2's `L`).
    pub power_iters: usize,
    /// RNG seed for power-iteration init (deterministic compression).
    pub seed: u64,
}

impl GearConfig {
    pub fn new(method: Method, n_heads: usize) -> GearConfig {
        GearConfig { method, n_heads, power_iters: 3, seed: 0xC0FFEE }
    }
}

/// A KV matrix compressed under some [`Method`].
#[derive(Debug, Clone)]
pub struct CompressedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// FP16 dense storage (Method::Fp16 only).
    pub dense: Option<Vec<f32>>,
    pub quant: Option<QuantizedMatrix>,
    pub sparse: Option<SparseCoo>,
    pub lowrank: Option<HeadwiseLowRank>,
}

/// Compress `x` (tokens × channels) of the given KV kind.
///
/// For the full GEAR method this realizes the paper's Eq. (4),
/// `X ≈ D̂ + L + S`: outliers `S` are filtered first, the remainder is
/// quantized into `D̂`, and a head-wise low-rank `L` is fitted to the
/// residual `R = X − D̂ − S`. Reconstruction is literally the sum of the
/// three stored terms:
///
/// ```
/// use gear_serve::gear::compose::{compress, GearConfig};
/// use gear_serve::gear::{KvKind, Method};
/// use gear_serve::tensor::Tensor;
/// use gear_serve::util::rng::Rng;
///
/// let x = Tensor::randn(&[256, 64], &mut Rng::new(9), 1.0);
/// // GEAR 2-bit: KIVI backbone, s = 2% outliers, rank-4 residual.
/// let c = compress(&x, KvKind::Key, &GearConfig::new(Method::gear_default(2), 4));
/// assert!(c.quant.is_some() && c.sparse.is_some() && c.lowrank.is_some());
///
/// // Eq. (4): reconstruct() is the component sum D̂ + L + S, bit for bit.
/// let mut manual = c.quant.as_ref().unwrap().dequantize();
/// c.lowrank.as_ref().unwrap().add_into(manual.data_mut());
/// c.sparse.as_ref().unwrap().add_into(manual.data_mut());
/// assert_eq!(manual.data(), c.reconstruct().data());
///
/// // Real stored bytes are the component sum too. At this toy width
/// // (d = 64) the rank-4 factors dominate, so the ratio is ~0.48; at
/// // LLaMA widths the same recipe lands near the backbone's 2-bit size.
/// assert!(c.kv_size_frac() < 0.5);
/// ```
pub fn compress(x: &Tensor, kind: KvKind, cfg: &GearConfig) -> CompressedMatrix {
    let (rows, cols) = (x.rows(), x.cols());
    let mut rng = Rng::new(cfg.seed ^ (rows as u64) << 32 ^ cols as u64);
    let mut out =
        CompressedMatrix { rows, cols, dense: None, quant: None, sparse: None, lowrank: None };

    match cfg.method {
        Method::Fp16 => {
            out.dense = Some(x.data().iter().map(|&v| to_f16_precision(v)).collect());
        }
        Method::QuantOnly { bits, backbone } => {
            out.quant = Some(super::timed_phase("quant", || {
                QuantizedMatrix::quantize(x, bits, backbone.scheme(kind))
            }));
        }
        Method::OutlierAware { bits, backbone, s } => {
            let (sp, rem) = super::timed_phase("sparse", || filter_outliers(x, s, kind.axis()));
            out.quant = Some(super::timed_phase("quant", || {
                QuantizedMatrix::quantize(&rem, bits, backbone.scheme(kind))
            }));
            out.sparse = Some(sp);
        }
        Method::GearL { bits, backbone, r } => {
            let q = super::timed_phase("quant", || {
                QuantizedMatrix::quantize(x, bits, backbone.scheme(kind))
            });
            let resid = residual(x, &q, None);
            out.lowrank = Some(super::timed_phase("lowrank", || {
                HeadwiseLowRank::decompose(
                    &resid, rows, cols, cfg.n_heads, r, cfg.power_iters, &mut rng,
                )
            }));
            out.quant = Some(q);
        }
        Method::Gear { bits, backbone, s, r } => {
            let (sp, rem) = super::timed_phase("sparse", || filter_outliers(x, s, kind.axis()));
            let q = super::timed_phase("quant", || {
                QuantizedMatrix::quantize(&rem, bits, backbone.scheme(kind))
            });
            // R = X − D̂ − S; `rem` is X − S so R = rem − D̂.
            let resid = residual(&rem, &q, None);
            out.lowrank = Some(super::timed_phase("lowrank", || {
                HeadwiseLowRank::decompose(
                    &resid, rows, cols, cfg.n_heads, r, cfg.power_iters, &mut rng,
                )
            }));
            out.quant = Some(q);
            out.sparse = Some(sp);
        }
        Method::LowRankOnly { r } => {
            out.lowrank = Some(HeadwiseLowRank::decompose(
                x.data(), rows, cols, cfg.n_heads, r, cfg.power_iters, &mut rng,
            ));
        }
        Method::SparseOnly { s } => {
            let (sp, _) = filter_outliers(x, s, kind.axis());
            out.sparse = Some(sp);
        }
    }
    if crate::trace::quality_capture_on() {
        stage_quality_record(x, kind, cfg, &out);
    }
    out
}

/// Stage a [`crate::trace::QualityStaged`] record for this compression:
/// achieved vs. predicted bytes plus the Frobenius norms of the Eq. (4)
/// components. Gated on an active quality-capture scope — the untraced
/// path pays one relaxed atomic load in [`crate::trace::quality_capture_on`]
/// and nothing else; the reconstruction below only runs while tracing.
fn stage_quality_record(x: &Tensor, kind: KvKind, cfg: &GearConfig, out: &CompressedMatrix) {
    let (rows, cols) = (out.rows, out.cols);
    let mut rec = vec![0.0f32; rows * cols];
    out.reconstruct_into(&mut rec);
    let mut lr = vec![0.0f32; rows * cols];
    if let Some(l) = &out.lowrank {
        l.add_into(&mut lr);
    }
    let mut err_sq = 0.0f64;
    let mut resid_sq = 0.0f64;
    for ((&xi, &ri), &li) in x.data().iter().zip(&rec).zip(&lr) {
        let e = f64::from(xi - ri);
        err_sq += e * e;
        // R = X − D̂ − S = (X − reconstruct) + L: the residual the
        // low-rank term was fitted to, recovered without re-dequantizing.
        let r = e + f64::from(li);
        resid_sq += r * r;
    }
    let lowrank_sq: f64 = lr.iter().map(|&v| f64::from(v) * f64::from(v)).sum();
    let outlier_sq: f64 = match &out.sparse {
        Some(sp) => {
            let mut s = vec![0.0f32; rows * cols];
            sp.add_into(&mut s);
            s.iter().map(|&v| f64::from(v) * f64::from(v)).sum()
        }
        None => 0.0,
    };
    crate::trace::stage_quality(crate::trace::QualityStaged {
        side: kind,
        rows: rows as u32,
        cols: cols as u32,
        bytes: out.nbytes() as u64,
        pred_bytes: super::size::predicted_nbytes(cfg, kind, rows, cols) as u64,
        err_fro: err_sq.sqrt() as f32,
        quant_resid_fro: resid_sq.sqrt() as f32,
        lowrank_fro: lowrank_sq.sqrt() as f32,
        outlier_fro: outlier_sq.sqrt() as f32,
    });
}

/// Dense residual `base − dequant(q)` (+ optional extra subtraction).
fn residual(base: &Tensor, q: &QuantizedMatrix, extra: Option<&[f32]>) -> Vec<f32> {
    let mut r = vec![0.0f32; base.len()];
    q.dequantize_into(&mut r);
    for (ri, &bi) in r.iter_mut().zip(base.data()) {
        *ri = bi - *ri;
    }
    if let Some(e) = extra {
        for (ri, &ei) in r.iter_mut().zip(e) {
            *ri -= ei;
        }
    }
    r
}

impl CompressedMatrix {
    /// Reconstruct the full matrix `D̂ + L + S`.
    pub fn reconstruct(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.rows, self.cols]);
        self.reconstruct_into(t.data_mut());
        t
    }

    pub fn reconstruct_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols);
        if let Some(d) = &self.dense {
            out.copy_from_slice(d);
            return;
        }
        match &self.quant {
            Some(q) => q.dequantize_into(out),
            None => out.fill(0.0),
        }
        if let Some(lr) = &self.lowrank {
            lr.add_into(out);
        }
        if let Some(sp) = &self.sparse {
            sp.add_into(out);
        }
    }

    /// Reconstruct token row `i` into `out` (cols long) — the decode hot
    /// path used by attention against the compressed cache.
    pub fn reconstruct_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        if let Some(d) = &self.dense {
            out.copy_from_slice(&d[i * self.cols..(i + 1) * self.cols]);
            return;
        }
        match &self.quant {
            Some(q) => q.dequantize_row_into(i, out),
            None => out.fill(0.0),
        }
        if let Some(lr) = &self.lowrank {
            lr.add_row_into(i, out);
        }
        if let Some(sp) = &self.sparse {
            sp.add_row_into(i, out);
        }
    }

    /// Real storage bytes of all present components.
    pub fn nbytes(&self) -> usize {
        let mut b = 0;
        if let Some(d) = &self.dense {
            b += d.len() * 2; // FP16 storage
        }
        if let Some(q) = &self.quant {
            b += q.nbytes();
        }
        if let Some(sp) = &self.sparse {
            b += sp.nbytes();
        }
        if let Some(lr) = &self.lowrank {
            b += lr.nbytes();
        }
        b
    }

    /// Size relative to FP16 (the paper's "KV size" column).
    pub fn kv_size_frac(&self) -> f64 {
        self.nbytes() as f64 / (self.rows * self.cols * 2) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gear::error::rel_error;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// KV-like matrix: per-channel scales are heavy-tailed (Key cache
    /// regime the paper analyzes).
    fn kv_matrix(rng: &mut Rng, n: usize, d: usize) -> Tensor {
        let mut chan_scale = vec![0.0f32; d];
        for s in chan_scale.iter_mut() {
            *s = (rng.normal_f32() * 1.2).exp(); // lognormal
        }
        let mut x = Tensor::zeros(&[n, d]);
        for i in 0..n {
            for j in 0..d {
                let mut v = rng.normal_f32() * chan_scale[j];
                if rng.next_f64() < 0.01 {
                    v *= 8.0;
                }
                x.data_mut()[i * d + j] = v;
            }
        }
        x
    }

    fn err_of(x: &Tensor, kind: KvKind, m: Method) -> f64 {
        let c = compress(x, kind, &GearConfig::new(m, 4));
        rel_error(x.data(), c.reconstruct().data())
    }

    #[test]
    fn gear_beats_quant_only_at_2bit() {
        let mut rng = Rng::new(50);
        let x = kv_matrix(&mut rng, 128, 64);
        for kind in [KvKind::Key, KvKind::Value] {
            let q = err_of(&x, kind, Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(32) });
            let gl =
                err_of(&x, kind, Method::GearL { bits: 2, backbone: Backbone::Kivi(32), r: 4 });
            let g = err_of(
                &x,
                kind,
                Method::Gear { bits: 2, backbone: Backbone::Kivi(32), s: 0.02, r: 4 },
            );
            assert!(gl < q, "{kind:?}: GEAR-L {gl} !< quant {q}");
            assert!(g < q, "{kind:?}: GEAR {g} !< quant {q}");
        }
    }

    #[test]
    fn full_gear_beats_each_single_technique() {
        // Fig 2a: no single technique matches the composite at its budget.
        let mut rng = Rng::new(51);
        let x = kv_matrix(&mut rng, 128, 64);
        let g = err_of(&x, KvKind::Key, Method::gear_default(2));
        let lr = err_of(&x, KvKind::Key, Method::LowRankOnly { r: 8 });
        let sp = err_of(&x, KvKind::Key, Method::SparseOnly { s: 0.1 });
        assert!(g < lr, "GEAR {g} !< lowrank-only {lr}");
        assert!(g < sp, "GEAR {g} !< sparse-only {sp}");
    }

    #[test]
    fn fp16_roundtrip_tiny_error() {
        let mut rng = Rng::new(52);
        let x = kv_matrix(&mut rng, 32, 32);
        let e = err_of(&x, KvKind::Key, Method::Fp16);
        assert!(e < 1e-3, "fp16 {e}");
    }

    #[test]
    fn row_reconstruction_matches_full() {
        let mut rng = Rng::new(53);
        let x = kv_matrix(&mut rng, 40, 32);
        for m in [
            Method::Fp16,
            Method::QuantOnly { bits: 4, backbone: Backbone::Kcvt },
            Method::gear_default(2),
            Method::gear_l_default(4),
            Method::SparseOnly { s: 0.05 },
            Method::LowRankOnly { r: 2 },
        ] {
            let c = compress(&x, KvKind::Key, &GearConfig::new(m, 4));
            let full = c.reconstruct();
            let mut row = vec![0.0f32; 32];
            for i in 0..40 {
                c.reconstruct_row_into(i, &mut row);
                for (a, b) in row.iter().zip(full.row(i)) {
                    assert!((a - b).abs() < 1e-6, "{m:?} row {i}");
                }
            }
        }
    }

    #[test]
    fn kv_size_ordering_matches_paper() {
        // KCVT (coarse groups) < KIVI (fine groups) at same bits; GEAR adds
        // a small overhead on top of its backbone.
        let mut rng = Rng::new(54);
        let x = kv_matrix(&mut rng, 256, 128);
        let sz = |m: Method| {
            compress(&x, KvKind::Key, &GearConfig::new(m, 4)).kv_size_frac()
        };
        let kcvt = sz(Method::QuantOnly { bits: 4, backbone: Backbone::Kcvt });
        let kivi = sz(Method::QuantOnly { bits: 4, backbone: Backbone::Kivi(64) });
        let gear = sz(Method::gear_default(4));
        let gearl = sz(Method::gear_l_default(4));
        assert!(kcvt < kivi, "KCVT {kcvt} !< KIVI {kivi}");
        assert!(gearl < gear, "GEAR-L {gearl} !< GEAR {gear}");
        assert!(gear < 0.5, "GEAR 4-bit size {gear} not < 50%");
        // All far below FP16.
        for s in [kcvt, kivi, gear, gearl] {
            assert!(s < 0.6);
        }
    }

    #[test]
    fn prop_gear_error_bounded_by_quant_error() {
        // Error reduction must not make things worse than its backbone.
        prop::check(
            |r| {
                let n = 16 + r.next_below(64) as usize;
                kv_matrix(&mut r.split(), n, 32)
            },
            |x| {
                let bits = 2;
                let bb = Backbone::Kivi(16);
                let q = err_of(x, KvKind::Value, Method::QuantOnly { bits, backbone: bb });
                let g =
                    err_of(x, KvKind::Value, Method::Gear { bits, backbone: bb, s: 0.02, r: 4 });
                if g <= q * 1.05 {
                    Ok(())
                } else {
                    Err(format!("GEAR {g} worse than quant-only {q}"))
                }
            },
        );
    }

    #[test]
    fn quality_probe_stages_exact_byte_accounting() {
        // Keep a tracer alive so the process-wide gate is open; the probe
        // additionally needs this thread's capture scope.
        let _tracer = crate::trace::Tracer::new(None);
        let mut rng = Rng::new(56);
        let x = kv_matrix(&mut rng, 64, 32);
        assert!(crate::trace::take_staged_quality().is_empty());
        let cfg = GearConfig::new(Method::gear_default(2), 4);
        crate::trace::set_quality_capture(true);
        let c = compress(&x, KvKind::Key, &cfg);
        crate::trace::set_quality_capture(false);
        let staged = crate::trace::take_staged_quality();
        assert_eq!(staged.len(), 1);
        let q = staged[0];
        assert_eq!(q.side, KvKind::Key);
        assert_eq!((q.rows as usize, q.cols as usize), (64, 32));
        // Achieved bytes are the real storage, and the analytic predictor
        // is exact, so the trace's achieved/predicted pair must agree.
        assert_eq!(q.bytes as usize, c.nbytes());
        assert_eq!(q.bytes, q.pred_bytes);
        // ‖X − X̂‖_F matches a direct recomputation.
        let err: f64 = x
            .data()
            .iter()
            .zip(c.reconstruct().data())
            .map(|(&a, &b)| {
                let e = f64::from(a - b);
                e * e
            })
            .sum::<f64>()
            .sqrt();
        assert!((f64::from(q.err_fro) - err).abs() < 1e-3 * err.max(1.0), "{} vs {err}", q.err_fro);
        // The low-rank fit cannot make the residual worse (Eq. 4's point).
        assert!(q.err_fro <= q.quant_resid_fro * 1.01, "{} > {}", q.err_fro, q.quant_resid_fro);
        assert!(q.lowrank_fro > 0.0 && q.outlier_fro > 0.0);
        // Outside a capture scope nothing stages, even with a live tracer.
        let _ = compress(&x, KvKind::Key, &cfg);
        assert!(crate::trace::take_staged_quality().is_empty());
    }

    #[test]
    fn nbytes_sums_components() {
        let mut rng = Rng::new(55);
        let x = kv_matrix(&mut rng, 64, 32);
        let c = compress(&x, KvKind::Key, &GearConfig::new(Method::gear_default(2), 4));
        let total = c.quant.as_ref().unwrap().nbytes()
            + c.sparse.as_ref().unwrap().nbytes()
            + c.lowrank.as_ref().unwrap().nbytes();
        assert_eq!(c.nbytes(), total);
    }
}
