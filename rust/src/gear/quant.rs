//! Uniform asymmetric quantization of KV matrices (the backbone `D̂`).
//!
//! Implements Eq. (2) of the paper for every grouping scheme evaluated:
//!
//! * **Per-token group-wise** (FlexGen): each row is split into groups of `g`
//!   contiguous channels; one scale/zero pair per group.
//! * **KIVI Key**: per-channel quantization with groups of `g` tokens within
//!   each channel. **KIVI Value**: per-token with groups of `g` channels
//!   (same layout as per-token group-wise).
//! * **KCVT**: the paper's lite backbone — per-channel Key / per-token Value
//!   with a *single* group spanning the whole vector (coarse per-vector
//!   grouping; minimal scale/zero overhead).
//!
//! Codes are bit-packed into `u32` words (16×2-bit, 8×4-bit or 4×8-bit per
//! word) in row-major element order, so the stored size is the real
//! compressed size, not an estimate. Scales and zero-points are rounded
//! through FP16 precision and accounted at 2 bytes each, exactly as the
//! paper stores them.

use crate::tensor::Tensor;
use crate::util::f16::to_f16_precision;

/// Axis a group runs along. `Row` = groups live inside a token vector
/// (per-token schemes); `Col` = groups live inside a channel vector
/// (per-channel schemes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    Row,
    Col,
}

/// Group extent within a vector along the grouping axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupSize {
    /// One group spans the entire vector (KCVT's per-vector grouping).
    Full,
    /// Fine-grained groups of `g` consecutive entries (FlexGen / KIVI).
    Fixed(usize),
}

/// A complete quantization scheme: which axis vectors run along and how
/// finely they are grouped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantScheme {
    pub axis: Axis,
    pub group: GroupSize,
}

impl QuantScheme {
    /// FlexGen-style per-token group-wise quantization.
    pub fn per_token_group(g: usize) -> Self {
        QuantScheme { axis: Axis::Row, group: GroupSize::Fixed(g) }
    }

    /// KIVI grouping for the given KV kind.
    pub fn kivi(kind: super::KvKind, g: usize) -> Self {
        QuantScheme { axis: kind.axis(), group: GroupSize::Fixed(g) }
    }

    /// KCVT grouping (whole-vector) for the given KV kind.
    pub fn kcvt(kind: super::KvKind) -> Self {
        QuantScheme { axis: kind.axis(), group: GroupSize::Full }
    }

    /// Effective group length for a matrix of shape (rows, cols).
    pub fn group_len(&self, rows: usize, cols: usize) -> usize {
        let vec_len = match self.axis {
            Axis::Row => cols,
            Axis::Col => rows,
        };
        match self.group {
            GroupSize::Full => vec_len,
            GroupSize::Fixed(g) => g.min(vec_len),
        }
    }
}

/// Bit-packed quantized matrix plus per-group scale/zero metadata.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub bits: u8,
    pub rows: usize,
    pub cols: usize,
    pub scheme: QuantScheme,
    /// Effective group length along the grouping axis.
    group_len: usize,
    /// Number of groups per vector (ceil division).
    groups_per_vec: usize,
    /// Bit-packed codes in row-major element order.
    packed: Vec<u32>,
    /// Per-group scale Δ (FP16-rounded, accounted 2 B each).
    scales: Vec<f32>,
    /// Per-group zero-point (group min; FP16-rounded, 2 B each).
    zeros: Vec<f32>,
}

const WORD_BITS: usize = 32;

#[inline]
fn codes_per_word(bits: u8) -> usize {
    WORD_BITS / bits as usize
}

impl QuantizedMatrix {
    /// Quantize `x` at `bits` precision under `scheme`.
    ///
    /// Supported bit widths: 2, 4, 8 (powers of two that tile a u32 word).
    ///
    /// This is the backbone term `D̂ = Quant_b(X)` of the paper's Eq. (4)
    /// decomposition `X ≈ D̂ + L + S`: a uniform asymmetric quantizer whose
    /// worst-case per-entry error is half a quantization step, leaving a
    /// small-magnitude residual for the low-rank term to capture.
    ///
    /// ```
    /// use gear_serve::gear::quant::{QuantScheme, QuantizedMatrix};
    /// use gear_serve::tensor::Tensor;
    /// use gear_serve::util::rng::Rng;
    ///
    /// let x = Tensor::randn(&[32, 64], &mut Rng::new(7), 1.0);
    /// let q = QuantizedMatrix::quantize(&x, 4, QuantScheme::per_token_group(16));
    ///
    /// // Stored size is real: bit-packed codes + FP16 scale/zero pairs.
    /// assert!(q.nbytes() < q.fp16_bytes() / 2);
    /// // Every entry of the dequantized backbone D̂ lies within half a
    /// // quantization step of the original (+ FP16 rounding slack).
    /// let d_hat = q.dequantize();
    /// let bound = q.max_step() * 0.5 + 1e-2;
    /// for (a, b) in x.data().iter().zip(d_hat.data()) {
    ///     assert!((a - b).abs() <= bound);
    /// }
    /// ```
    pub fn quantize(x: &Tensor, bits: u8, scheme: QuantScheme) -> QuantizedMatrix {
        assert!(
            matches!(bits, 2 | 4 | 8),
            "unsupported bit width {bits}; GEAR evaluates 2/4/8-bit"
        );
        let (rows, cols) = (x.rows(), x.cols());
        let glen = scheme.group_len(rows, cols);
        let vec_len = match scheme.axis {
            Axis::Row => cols,
            Axis::Col => rows,
        };
        let n_vecs = match scheme.axis {
            Axis::Row => rows,
            Axis::Col => cols,
        };
        let groups_per_vec = vec_len.div_ceil(glen);
        let n_groups = n_vecs * groups_per_vec;

        let mut scales = vec![0.0f32; n_groups];
        let mut zeros = vec![0.0f32; n_groups];
        let levels = ((1u32 << bits) - 1) as f32;

        // Pass 1: per-group min/max.
        let mut mins = vec![f32::INFINITY; n_groups];
        let mut maxs = vec![f32::NEG_INFINITY; n_groups];
        let data = x.data();
        for i in 0..rows {
            for j in 0..cols {
                let gi = group_index(scheme.axis, groups_per_vec, glen, i, j);
                let v = data[i * cols + j];
                if v < mins[gi] {
                    mins[gi] = v;
                }
                if v > maxs[gi] {
                    maxs[gi] = v;
                }
            }
        }
        for gi in 0..n_groups {
            // Degenerate groups (constant values) get scale 0; dequant
            // reproduces the zero-point exactly.
            let delta = (maxs[gi] - mins[gi]) / levels;
            scales[gi] = to_f16_precision(delta);
            zeros[gi] = to_f16_precision(mins[gi]);
        }

        // Pass 2: quantize + pack.
        let cpw = codes_per_word(bits);
        let n = rows * cols;
        let mut packed = vec![0u32; n.div_ceil(cpw)];
        for i in 0..rows {
            for j in 0..cols {
                let gi = group_index(scheme.axis, groups_per_vec, glen, i, j);
                let v = data[i * cols + j];
                let code = if scales[gi] > 0.0 {
                    (((v - zeros[gi]) / scales[gi]).round().clamp(0.0, levels)) as u32
                } else {
                    0
                };
                let e = i * cols + j;
                packed[e / cpw] |= code << ((e % cpw) * bits as usize);
            }
        }

        QuantizedMatrix {
            bits,
            rows,
            cols,
            scheme,
            group_len: glen,
            groups_per_vec,
            packed,
            scales,
            zeros,
        }
    }

    /// Raw code of element (i, j).
    #[inline]
    pub fn code(&self, i: usize, j: usize) -> u32 {
        let e = i * self.cols + j;
        let cpw = codes_per_word(self.bits);
        let mask = (1u32 << self.bits) - 1;
        (self.packed[e / cpw] >> ((e % cpw) * self.bits as usize)) & mask
    }

    /// Dequantized value of element (i, j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let gi = group_index(self.scheme.axis, self.groups_per_vec, self.group_len, i, j);
        self.zeros[gi] + self.scales[gi] * self.code(i, j) as f32
    }

    /// Dequantize the whole matrix.
    pub fn dequantize(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        self.dequantize_into(out.data_mut());
        out
    }

    /// Dequantize into caller scratch (row-major, rows*cols long).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols);
        let mut plan = self.row_plan();
        for i in 0..self.rows {
            self.dequantize_row_planned(i, &mut plan, &mut out[i * self.cols..(i + 1) * self.cols]);
        }
    }

    /// Dequantize row `i` into `out` (cols long). This is the decode hot
    /// path: attention reads token rows.
    ///
    /// §Perf iteration 1: codes are unpacked word-at-a-time (16×2-bit /
    /// 8×4-bit / 4×8-bit per u32) instead of per-element shifts, and the
    /// per-column scale/zero lookups of the Col axis go through a small
    /// gather loop free of div/mod in the inner body.
    pub fn dequantize_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        // Row codes are contiguous: unpack them first, then apply affine.
        self.unpack_row_codes(i, out);
        match self.scheme.axis {
            Axis::Row => {
                let gbase = i * self.groups_per_vec;
                for g in 0..self.groups_per_vec {
                    let lo = g * self.group_len;
                    let hi = ((g + 1) * self.group_len).min(self.cols);
                    let scale = self.scales[gbase + g];
                    let zero = self.zeros[gbase + g];
                    for v in &mut out[lo..hi] {
                        *v = zero + scale * *v;
                    }
                }
            }
            Axis::Col => {
                let sub = i / self.group_len;
                let gpv = self.groups_per_vec;
                for (j, v) in out.iter_mut().enumerate() {
                    let gi = j * gpv + sub;
                    *v = self.zeros[gi] + self.scales[gi] * *v;
                }
            }
        }
    }

    /// Create a reusable row-sweep plan (§Perf iteration 2): for Col-axis
    /// schemes, per-column scale/zero vectors are gathered once per
    /// sub-block of `group_len` consecutive rows instead of per element.
    pub fn row_plan(&self) -> RowDequantPlan {
        RowDequantPlan {
            cur_sub: usize::MAX,
            scale_row: vec![0.0; self.cols],
            zero_row: vec![0.0; self.cols],
        }
    }

    /// Dequantize row `i` using (and updating) a sweep plan. Equivalent to
    /// [`Self::dequantize_row_into`] but amortizes Col-axis gathers across
    /// consecutive rows — the fused-attention fast path.
    pub fn dequantize_row_planned(&self, i: usize, plan: &mut RowDequantPlan, out: &mut [f32]) {
        match self.scheme.axis {
            Axis::Row => self.dequantize_row_into(i, out),
            Axis::Col => {
                let sub = i / self.group_len;
                if sub != plan.cur_sub {
                    let gpv = self.groups_per_vec;
                    for j in 0..self.cols {
                        let gi = j * gpv + sub;
                        plan.scale_row[j] = self.scales[gi];
                        plan.zero_row[j] = self.zeros[gi];
                    }
                    plan.cur_sub = sub;
                }
                self.unpack_row_codes(i, out);
                for ((v, &s), &z) in
                    out.iter_mut().zip(&plan.scale_row).zip(&plan.zero_row)
                {
                    *v = z + s * *v;
                }
            }
        }
    }

    /// Unpack the raw integer codes of row `i` into `out` as f32.
    #[inline]
    fn unpack_row_codes(&self, i: usize, out: &mut [f32]) {
        let bits = self.bits as usize;
        let cpw = codes_per_word(self.bits);
        let mask = (1u32 << bits) - 1;
        let base = i * self.cols;
        let mut j = 0usize;
        // Head: align to a word boundary.
        while j < self.cols && (base + j) % cpw != 0 {
            let e = base + j;
            out[j] = ((self.packed[e / cpw] >> ((e % cpw) * bits)) & mask) as f32;
            j += 1;
        }
        // Body: whole words.
        while j + cpw <= self.cols {
            let mut w = self.packed[(base + j) / cpw];
            for k in 0..cpw {
                out[j + k] = (w & mask) as f32;
                w >>= bits;
            }
            j += cpw;
        }
        // Tail.
        while j < self.cols {
            let e = base + j;
            out[j] = ((self.packed[e / cpw] >> ((e % cpw) * bits)) & mask) as f32;
            j += 1;
        }
    }

    /// Worst-case per-entry quantization error: half a quantization step of
    /// the entry's group (plus FP16 rounding of scale/zero, which is why the
    /// bound below carries a small epsilon).
    pub fn max_step(&self) -> f32 {
        self.scales.iter().cloned().fold(0.0, f32::max)
    }

    pub fn n_groups(&self) -> usize {
        self.scales.len()
    }

    /// Real storage bytes: packed words + FP16 scale/zero pairs.
    pub fn nbytes(&self) -> usize {
        self.packed.len() * 4 + self.scales.len() * 2 + self.zeros.len() * 2
    }

    /// Bytes the same matrix would occupy in FP16.
    pub fn fp16_bytes(&self) -> usize {
        self.rows * self.cols * 2
    }
}

/// Scratch state for a planned row sweep (see `QuantizedMatrix::row_plan`).
///
/// A plan caches the per-column scale/zero gather of one sub-block of one
/// matrix; reusing it against a *different* matrix requires [`Self::prepare`]
/// first, which invalidates the cached gather and re-sizes the buffers.
#[derive(Debug, Clone)]
pub struct RowDequantPlan {
    cur_sub: usize,
    scale_row: Vec<f32>,
    zero_row: Vec<f32>,
}

impl Default for RowDequantPlan {
    fn default() -> Self {
        RowDequantPlan { cur_sub: usize::MAX, scale_row: Vec::new(), zero_row: Vec::new() }
    }
}

impl RowDequantPlan {
    /// Re-arm the plan for a (possibly different) matrix with `cols`
    /// columns. Cheap when the size is unchanged.
    pub fn prepare(&mut self, cols: usize) {
        self.cur_sub = usize::MAX;
        self.scale_row.resize(cols, 0.0);
        self.zero_row.resize(cols, 0.0);
    }
}

/// Flat group index of element (i, j).
///
/// Row-axis: vector = row `i`, groups tile columns. Col-axis: vector =
/// column `j`, groups tile rows. Group ids are vector-major.
#[inline]
fn group_index(axis: Axis, groups_per_vec: usize, glen: usize, i: usize, j: usize) -> usize {
    match axis {
        Axis::Row => i * groups_per_vec + j / glen,
        Axis::Col => j * groups_per_vec + i / glen,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn randmat(r: &mut Rng, rows: usize, cols: usize) -> Tensor {
        Tensor::new(&[rows, cols], prop::gen_kv_like(r, rows * cols))
    }

    #[test]
    fn roundtrip_error_within_half_step() {
        let mut r = Rng::new(10);
        let x = randmat(&mut r, 32, 64);
        for bits in [2u8, 4, 8] {
            for scheme in [
                QuantScheme::per_token_group(16),
                QuantScheme::kcvt(crate::gear::KvKind::Key),
                QuantScheme::kcvt(crate::gear::KvKind::Value),
                QuantScheme::kivi(crate::gear::KvKind::Key, 8),
            ] {
                let q = QuantizedMatrix::quantize(&x, bits, scheme);
                let y = q.dequantize();
                let bound = q.max_step() * 0.5 + 1e-2; // + fp16 rounding slack
                for (a, b) in x.data().iter().zip(y.data()) {
                    assert!(
                        (a - b).abs() <= bound,
                        "bits={bits} scheme={scheme:?}: |{a}-{b}| > {bound}"
                    );
                }
            }
        }
    }

    #[test]
    fn eight_bit_nearly_exact() {
        let mut r = Rng::new(11);
        let x = Tensor::randn(&[16, 16], &mut r, 1.0);
        let q = QuantizedMatrix::quantize(&x, 8, QuantScheme::per_token_group(16));
        let y = q.dequantize();
        let err = crate::tensor::ops::fro_dist(x.data(), y.data())
            / crate::tensor::ops::fro_norm(x.data());
        assert!(err < 0.01, "8-bit relative error {err}");
    }

    #[test]
    fn finer_groups_do_not_hurt() {
        // Smaller group size => error must not increase (paper's motivation
        // for fine-grained grouping).
        let mut r = Rng::new(12);
        let x = randmat(&mut r, 64, 64);
        let mut prev = f64::INFINITY;
        for g in [64usize, 16, 4] {
            let q = QuantizedMatrix::quantize(&x, 2, QuantScheme::per_token_group(g));
            let err = crate::tensor::ops::fro_dist(x.data(), q.dequantize().data());
            assert!(err <= prev * 1.02, "g={g}: err {err} > prev {prev}");
            prev = err;
        }
    }

    #[test]
    fn constant_matrix_exact() {
        let x = Tensor::filled(&[8, 8], 3.25);
        let q = QuantizedMatrix::quantize(&x, 2, QuantScheme::per_token_group(4));
        for v in q.dequantize().data() {
            assert_eq!(*v, 3.25);
        }
    }

    #[test]
    fn packing_is_dense() {
        let mut r = Rng::new(13);
        let x = randmat(&mut r, 100, 64); // 6400 entries
        let q2 = QuantizedMatrix::quantize(&x, 2, QuantScheme::per_token_group(64));
        // 6400 * 2 bits = 1600 bytes of codes.
        assert_eq!(q2.packed.len() * 4, 1600);
        let q4 = QuantizedMatrix::quantize(&x, 4, QuantScheme::per_token_group(64));
        assert_eq!(q4.packed.len() * 4, 3200);
    }

    #[test]
    fn kcvt_overhead_smaller_than_kivi() {
        let mut r = Rng::new(14);
        let x = randmat(&mut r, 256, 128);
        let kcvt = QuantizedMatrix::quantize(&x, 2, QuantScheme::kcvt(crate::gear::KvKind::Key));
        let kivi =
            QuantizedMatrix::quantize(&x, 2, QuantScheme::kivi(crate::gear::KvKind::Key, 32));
        assert!(kcvt.n_groups() < kivi.n_groups());
        assert!(kcvt.nbytes() < kivi.nbytes());
    }

    #[test]
    fn row_dequant_matches_full() {
        let mut r = Rng::new(15);
        let x = randmat(&mut r, 33, 48);
        for scheme in [
            QuantScheme::per_token_group(16),
            QuantScheme::kivi(crate::gear::KvKind::Key, 8),
            QuantScheme::kcvt(crate::gear::KvKind::Key),
        ] {
            let q = QuantizedMatrix::quantize(&x, 4, scheme);
            let full = q.dequantize();
            let mut row = vec![0.0f32; 48];
            for i in 0..33 {
                q.dequantize_row_into(i, &mut row);
                assert_eq!(&row[..], full.row(i), "scheme {scheme:?} row {i}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_bounded() {
        prop::check(
            |r| {
                let (rows, cols) = prop::gen_shape(r, 48, 48);
                let bits = *r.choose(&[2u8, 4, 8]);
                let g = 1 + r.next_below(16) as usize;
                (randmat(r, rows, cols), bits, g)
            },
            |(x, bits, g)| {
                let q = QuantizedMatrix::quantize(x, *bits, QuantScheme::per_token_group(*g));
                let y = q.dequantize();
                let bound = q.max_step() * 0.5 + 1e-2;
                for (a, b) in x.data().iter().zip(y.data()) {
                    prop_assert!((a - b).abs() <= bound, "|{a}-{b}| > {bound}");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_codes_within_levels() {
        prop::check(
            |r| {
                let (rows, cols) = prop::gen_shape(r, 20, 20);
                (randmat(r, rows, cols), *r.choose(&[2u8, 4]))
            },
            |(x, bits)| {
                let q = QuantizedMatrix::quantize(
                    x,
                    *bits,
                    QuantScheme::kcvt(crate::gear::KvKind::Value),
                );
                let max = (1u32 << bits) - 1;
                for i in 0..x.rows() {
                    for j in 0..x.cols() {
                        prop_assert!(q.code(i, j) <= max, "code oob");
                    }
                }
                Ok(())
            },
        );
    }
}
