//! Adaptive low-rank budget allocation — the paper's §6.1 future-work
//! extension, implemented.
//!
//! GEAR uses one rank `r` for every head; the paper notes that Key/Value
//! importance "varies significantly across layers and heads" and that
//! adaptively allocating the low-rank budget improves accuracy. Here the
//! total budget `R = r · H` is distributed across heads proportionally to
//! each head's residual spectral mass (estimated from the Frobenius norm of
//! the residual block — a cheap, request-path-compatible proxy for the
//! leading singular values), with every head keeping at least rank 1 when
//! its residual is non-trivial.

use crate::util::rng::Rng;

use super::lowrank::{power_iter_lowrank, HeadwiseLowRank};

/// Allocate integer ranks summing to `total` across `weights.len()` heads,
/// proportional to `weights` (largest-remainder method). Heads with zero
/// weight get rank 0; others get at least 1 when the budget allows.
pub fn allocate_ranks(weights: &[f64], total: usize) -> Vec<usize> {
    let n = weights.len();
    if n == 0 || total == 0 {
        return vec![0; n];
    }
    let sum: f64 = weights.iter().sum();
    if sum <= 0.0 {
        // Degenerate: spread evenly.
        let base = total / n;
        let mut out = vec![base; n];
        for slot in out.iter_mut().take(total % n) {
            *slot += 1;
        }
        return out;
    }
    // Ideal fractional shares.
    let shares: Vec<f64> = weights.iter().map(|w| w.max(0.0) / sum * total as f64).collect();
    let mut ranks: Vec<usize> = shares.iter().map(|s| s.floor() as usize).collect();
    // Guarantee >=1 for positive-weight heads while any budget remains.
    let mut used: usize = ranks.iter().sum();
    for i in 0..n {
        if weights[i] > 0.0 && ranks[i] == 0 && used < total {
            ranks[i] = 1;
            used += 1;
        }
    }
    // Distribute the remainder by largest fractional part.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let fa = shares[a] - shares[a].floor();
        let fb = shares[b] - shares[b].floor();
        fb.partial_cmp(&fa).unwrap()
    });
    let mut k = 0;
    while used < total && k < n {
        let i = order[k];
        if weights[i] > 0.0 {
            ranks[i] += 1;
            used += 1;
        }
        k += 1;
        if k == n && used < total {
            k = 0; // keep cycling if budget still remains
        }
    }
    ranks
}

/// Head-wise low-rank decomposition with an adaptive per-head rank budget.
///
/// `total_rank` plays the role of `r · n_heads` in uniform GEAR; heads with
/// larger residual energy receive more of it.
pub fn adaptive_decompose(
    x: &[f32],
    n: usize,
    d: usize,
    n_heads: usize,
    total_rank: usize,
    iters: usize,
    rng: &mut Rng,
) -> HeadwiseLowRank {
    assert_eq!(x.len(), n * d);
    assert!(n_heads >= 1 && d % n_heads == 0);
    let dh = d / n_heads;

    // Residual energy per head (Frobenius mass of the block).
    let mut energy = vec![0.0f64; n_heads];
    for i in 0..n {
        for h in 0..n_heads {
            for j in 0..dh {
                let v = x[i * d + h * dh + j] as f64;
                energy[h] += v * v;
            }
        }
    }
    let ranks = allocate_ranks(&energy, total_rank);

    let mut heads = Vec::with_capacity(n_heads);
    let mut sub = vec![0.0f32; n * dh];
    for h in 0..n_heads {
        for i in 0..n {
            sub[i * dh..(i + 1) * dh].copy_from_slice(&x[i * d + h * dh..i * d + (h + 1) * dh]);
        }
        // Rank 0 heads still need a placeholder factor pair (rank 1 of a
        // zero matrix reconstructs zero); use rank max(1, r) on the data or
        // zeros for truly empty budget.
        let r = ranks[h];
        if r == 0 {
            heads.push(super::lowrank::LowRank {
                n,
                d: dh,
                r: 1,
                a: vec![0.0; n],
                b: vec![0.0; dh],
            });
        } else {
            heads.push(power_iter_lowrank(&sub, n, dh, r, iters, rng));
        }
    }
    HeadwiseLowRank { n, d, n_heads, heads }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro_dist, matmul_into};
    use crate::util::rng::Rng;

    #[test]
    fn allocation_sums_to_total() {
        for (w, total) in [
            (vec![1.0, 1.0, 1.0, 1.0], 16usize),
            (vec![10.0, 1.0, 1.0, 1.0], 16),
            (vec![0.0, 5.0, 5.0, 0.0], 8),
            (vec![1.0], 4),
        ] {
            let r = allocate_ranks(&w, total);
            assert_eq!(r.iter().sum::<usize>(), total, "{w:?}");
        }
    }

    #[test]
    fn allocation_follows_weights() {
        let r = allocate_ranks(&[8.0, 4.0, 2.0, 2.0], 16);
        assert!(r[0] >= r[1] && r[1] >= r[2], "{r:?}");
        assert_eq!(r.iter().sum::<usize>(), 16);
    }

    #[test]
    fn zero_weights_get_nothing_when_others_positive() {
        let r = allocate_ranks(&[0.0, 3.0, 0.0, 1.0], 8);
        assert_eq!(r[0], 0);
        assert_eq!(r[2], 0);
        assert_eq!(r.iter().sum::<usize>(), 8);
    }

    #[test]
    fn degenerate_all_zero_spreads_evenly() {
        let r = allocate_ranks(&[0.0; 4], 8);
        assert_eq!(r, vec![2, 2, 2, 2]);
    }

    /// The §6.1 claim: with skewed per-head residual energy, adaptive
    /// allocation beats uniform at the same total budget.
    #[test]
    fn adaptive_beats_uniform_on_skewed_heads() {
        let mut rng = Rng::new(201);
        let (n, d, heads) = (96usize, 64usize, 4usize);
        let dh = d / heads;
        // Head 0: rank-6 structure with big scale; heads 1-3: tiny noise.
        let mut x = vec![0.0f32; n * d];
        let mut u = vec![0.0f32; n * 6];
        let mut v = vec![0.0f32; 6 * dh];
        rng.fill_normal(&mut u, 0.0, 1.0);
        rng.fill_normal(&mut v, 0.0, 1.0);
        let mut blk = vec![0.0f32; n * dh];
        matmul_into(&u, &v, n, 6, dh, &mut blk);
        for i in 0..n {
            for j in 0..dh {
                x[i * d + j] = blk[i * dh + j] * 3.0;
            }
            for j in dh..d {
                x[i * d + j] = rng.normal_f32() * 0.05;
            }
        }
        let total = 8; // uniform would give r=2 per head
        let adaptive = adaptive_decompose(&x, n, d, heads, total, 4, &mut Rng::new(5));
        let uniform = crate::gear::lowrank::HeadwiseLowRank::decompose(
            &x, n, d, heads, total / heads, 4, &mut Rng::new(5),
        );
        let err = |hw: &crate::gear::lowrank::HeadwiseLowRank| {
            let mut recon = vec![0.0f32; n * d];
            hw.add_into(&mut recon);
            fro_dist(&x, &recon)
        };
        let ea = err(&adaptive);
        let eu = err(&uniform);
        assert!(ea < eu * 0.8, "adaptive {ea} !< uniform {eu}");
    }

    #[test]
    fn adaptive_bytes_scale_with_budget() {
        let mut rng = Rng::new(202);
        let mut x = vec![0.0f32; 32 * 32];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let small = adaptive_decompose(&x, 32, 32, 4, 4, 3, &mut rng);
        let large = adaptive_decompose(&x, 32, 32, 4, 16, 3, &mut rng);
        assert!(large.nbytes() > small.nbytes());
    }
}
