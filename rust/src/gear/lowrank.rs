//! Low-rank residual approximation `L_h = A_h B_hᵀ` (Eq. 6 / Algorithm 2).
//!
//! The residual `R = X − D̂ − S` is split head-wise along the channel axis
//! and each `R_h ∈ ℝ^{n×d_H}` is approximated at rank `r` with the
//! power-iteration solver of Vogels et al. (PowerSGD), exactly the paper's
//! Algorithm 2: alternate `A = R B`, `B = Rᵀ A` with a QR orthonormalization
//! on the final sweep. This captures the top-r singular directions at
//! O(L · n · d_H · r) cost — no full SVD on the request path.
//!
//! Factors are FP16-rounded on store (2 B/entry accounting), matching the
//! paper's full-precision-FP16 setting.

use crate::tensor::ops::matmul_into;
use crate::tensor::Tensor;
use crate::util::f16::to_f16_precision;
use crate::util::rng::Rng;

/// Rank-r factorization of a single matrix: `L = A Bᵀ`,
/// `A ∈ ℝ^{n×r}`, `B ∈ ℝ^{d×r}`.
#[derive(Debug, Clone)]
pub struct LowRank {
    pub n: usize,
    pub d: usize,
    pub r: usize,
    /// Row-major n×r.
    pub a: Vec<f32>,
    /// Row-major d×r.
    pub b: Vec<f32>,
}

impl LowRank {
    /// Add `A Bᵀ` into a dense n×d buffer.
    pub fn add_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n * self.d);
        for i in 0..self.n {
            let arow = &self.a[i * self.r..(i + 1) * self.r];
            let orow = &mut out[i * self.d..(i + 1) * self.d];
            for j in 0..self.d {
                let brow = &self.b[j * self.r..(j + 1) * self.r];
                let mut s = 0.0f32;
                for k in 0..self.r {
                    s += arow[k] * brow[k];
                }
                orow[j] += s;
            }
        }
    }

    /// Add row `i` of `A Bᵀ` into a d-long buffer (decode hot path).
    #[inline]
    pub fn add_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let arow = &self.a[i * self.r..(i + 1) * self.r];
        for j in 0..self.d {
            let brow = &self.b[j * self.r..(j + 1) * self.r];
            let mut s = 0.0f32;
            for k in 0..self.r {
                s += arow[k] * brow[k];
            }
            out[j] += s;
        }
    }

    pub fn to_dense(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.n, self.d]);
        self.add_into(t.data_mut());
        t
    }

    /// Real storage bytes at FP16.
    pub fn nbytes(&self) -> usize {
        (self.a.len() + self.b.len()) * 2
    }
}

/// Modified Gram–Schmidt QR: orthonormalize the `r` columns of the
/// column-major-interpreted (rows×r, row-major storage) matrix in place.
/// Returns false for a numerically-degenerate column (left as zeros).
pub fn orthonormalize_columns(m: &mut [f32], rows: usize, r: usize) -> bool {
    let mut ok = true;
    for c in 0..r {
        // Pre-projection norm, for a relative degeneracy threshold.
        let mut norm0 = 0.0f64;
        for i in 0..rows {
            norm0 += (m[i * r + c] as f64).powi(2);
        }
        let norm0 = norm0.sqrt();
        // Subtract projections on previous columns.
        for p in 0..c {
            let mut dot = 0.0f64;
            for i in 0..rows {
                dot += m[i * r + c] as f64 * m[i * r + p] as f64;
            }
            for i in 0..rows {
                m[i * r + c] -= dot as f32 * m[i * r + p];
            }
        }
        let mut norm = 0.0f64;
        for i in 0..rows {
            norm += (m[i * r + c] as f64).powi(2);
        }
        let norm = norm.sqrt();
        if norm < 1e-6 * norm0.max(1e-30) || norm < 1e-30 {
            for i in 0..rows {
                m[i * r + c] = 0.0;
            }
            ok = false;
            continue;
        }
        let inv = (1.0 / norm) as f32;
        for i in 0..rows {
            m[i * r + c] *= inv;
        }
    }
    ok
}

/// Power-iteration low-rank solver (paper Algorithm 2).
///
/// `x` is row-major n×d. `iters` is the loop count `L` (the paper uses a
/// small constant; 2–4 suffices given the fast spectrum decay of
/// quantization residuals — see Fig 2b).
///
/// This fits the residual term `L = A Bᵀ` of Eq. (4)'s `X ≈ D̂ + L + S`;
/// in the full recipe it runs on `R = X − D̂ − S` (per head, via
/// [`HeadwiseLowRank`]). On an exactly low-rank input it recovers the
/// matrix to working precision:
///
/// ```
/// use gear_serve::gear::lowrank::power_iter_lowrank;
/// use gear_serve::tensor::ops::{fro_dist, fro_norm, matmul_into};
/// use gear_serve::util::rng::Rng;
///
/// // An exactly rank-2 matrix: X = U Vᵀ.
/// let (n, d, k) = (24, 16, 2);
/// let mut rng = Rng::new(3);
/// let (mut u, mut v) = (vec![0.0f32; n * k], vec![0.0f32; k * d]);
/// rng.fill_normal(&mut u, 0.0, 1.0);
/// rng.fill_normal(&mut v, 0.0, 1.0);
/// let mut x = vec![0.0f32; n * d];
/// matmul_into(&u, &v, n, k, d, &mut x);
///
/// let lr = power_iter_lowrank(&x, n, d, k, 4, &mut rng);
/// let rel = fro_dist(&x, lr.to_dense().data()) / fro_norm(&x);
/// assert!(rel < 5e-3, "rank-2 recovery rel err {rel}");
/// ```
pub fn power_iter_lowrank(
    x: &[f32],
    n: usize,
    d: usize,
    r: usize,
    iters: usize,
    rng: &mut Rng,
) -> LowRank {
    assert_eq!(x.len(), n * d);
    let r = r.min(n).min(d).max(1);
    let iters = iters.max(1);

    // Random init of B (d×r).
    let mut b = vec![0.0f32; d * r];
    rng.fill_normal(&mut b, 0.0, 1.0);
    let mut a = vec![0.0f32; n * r];

    for l in 0..iters {
        let last = l == iters - 1;
        if last {
            orthonormalize_columns(&mut b, d, r);
        }
        // A = X B     (n×d @ d×r)
        matmul_into(x, &b, n, d, r, &mut a);
        if last {
            orthonormalize_columns(&mut a, n, r);
        }
        // B = Xᵀ A    (d×n @ n×r) == (Aᵀ X)ᵀ; computed as B[j,k] = Σ_i X[i,j] A[i,k]
        b.fill(0.0);
        for i in 0..n {
            let xrow = &x[i * d..(i + 1) * d];
            let arow = &a[i * r..(i + 1) * r];
            for (j, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let brow = &mut b[j * r..(j + 1) * r];
                for k in 0..r {
                    brow[k] += xv * arow[k];
                }
            }
        }
    }

    // FP16-round the stored factors (storage precision of the paper).
    for v in a.iter_mut() {
        *v = to_f16_precision(*v);
    }
    for v in b.iter_mut() {
        *v = to_f16_precision(*v);
    }
    LowRank { n, d, r, a, b }
}

/// Head-wise low-rank decomposition: split the channel axis into `n_heads`
/// contiguous blocks of `d_H = d / n_heads` and factor each independently
/// (attention heads encode distinct subspaces — §3 of the paper).
#[derive(Debug, Clone)]
pub struct HeadwiseLowRank {
    pub n: usize,
    pub d: usize,
    pub n_heads: usize,
    pub heads: Vec<LowRank>,
}

impl HeadwiseLowRank {
    pub fn decompose(
        x: &[f32],
        n: usize,
        d: usize,
        n_heads: usize,
        r: usize,
        iters: usize,
        rng: &mut Rng,
    ) -> HeadwiseLowRank {
        assert_eq!(x.len(), n * d);
        assert!(n_heads >= 1 && d % n_heads == 0, "d={d} not divisible by heads={n_heads}");
        let dh = d / n_heads;
        let mut heads = Vec::with_capacity(n_heads);
        let mut sub = vec![0.0f32; n * dh];
        for h in 0..n_heads {
            for i in 0..n {
                sub[i * dh..(i + 1) * dh]
                    .copy_from_slice(&x[i * d + h * dh..i * d + (h + 1) * dh]);
            }
            heads.push(power_iter_lowrank(&sub, n, dh, r, iters, rng));
        }
        HeadwiseLowRank { n, d, n_heads, heads }
    }

    /// Add `concat_h(A_h B_hᵀ)` into a dense n×d buffer.
    pub fn add_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n * self.d);
        let dh = self.d / self.n_heads;
        for (h, lr) in self.heads.iter().enumerate() {
            for i in 0..self.n {
                let arow = &lr.a[i * lr.r..(i + 1) * lr.r];
                let orow = &mut out[i * self.d + h * dh..i * self.d + (h + 1) * dh];
                for j in 0..dh {
                    let brow = &lr.b[j * lr.r..(j + 1) * lr.r];
                    let mut s = 0.0f32;
                    for k in 0..lr.r {
                        s += arow[k] * brow[k];
                    }
                    orow[j] += s;
                }
            }
        }
    }

    /// Add row `i` into a d-long buffer.
    pub fn add_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let dh = self.d / self.n_heads;
        for (h, lr) in self.heads.iter().enumerate() {
            lr.add_row_into(i, &mut out[h * dh..(h + 1) * dh]);
        }
    }

    pub fn nbytes(&self) -> usize {
        self.heads.iter().map(|h| h.nbytes()).sum()
    }

    pub fn rank(&self) -> usize {
        self.heads.first().map(|h| h.r).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{fro_dist, fro_norm};
    use crate::util::prop;

    /// Build an exactly rank-k matrix.
    fn rank_k(rng: &mut Rng, n: usize, d: usize, k: usize) -> Vec<f32> {
        let mut u = vec![0.0f32; n * k];
        let mut v = vec![0.0f32; k * d];
        rng.fill_normal(&mut u, 0.0, 1.0);
        rng.fill_normal(&mut v, 0.0, 1.0);
        let mut x = vec![0.0f32; n * d];
        matmul_into(&u, &v, n, k, d, &mut x);
        x
    }

    #[test]
    fn recovers_exact_low_rank() {
        let mut rng = Rng::new(30);
        let (n, d, k) = (64, 32, 3);
        let x = rank_k(&mut rng, n, d, k);
        let lr = power_iter_lowrank(&x, n, d, k, 4, &mut rng);
        let recon = lr.to_dense();
        let rel = fro_dist(&x, recon.data()) / fro_norm(&x);
        assert!(rel < 5e-3, "rank-{k} recovery rel err {rel}");
    }

    #[test]
    fn qr_produces_orthonormal_columns() {
        let mut rng = Rng::new(31);
        let (rows, r) = (40, 5);
        let mut m = vec![0.0f32; rows * r];
        rng.fill_normal(&mut m, 0.0, 1.0);
        assert!(orthonormalize_columns(&mut m, rows, r));
        for c1 in 0..r {
            for c2 in 0..=c1 {
                let mut dot = 0.0f64;
                for i in 0..rows {
                    dot += m[i * r + c1] as f64 * m[i * r + c2] as f64;
                }
                let expect = if c1 == c2 { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-5, "Q^T Q [{c1},{c2}] = {dot}");
            }
        }
    }

    #[test]
    fn qr_handles_dependent_columns() {
        // Two identical columns: second must be zeroed, not NaN.
        let mut m = vec![1.0f32, 1.0, 2.0, 2.0, 3.0, 3.0]; // 3x2
        let ok = orthonormalize_columns(&mut m, 3, 2);
        assert!(!ok);
        assert!(m.iter().all(|v| v.is_finite()));
        assert_eq!(m[1], 0.0);
    }

    #[test]
    fn higher_rank_reduces_error() {
        let mut rng = Rng::new(32);
        let (n, d) = (48, 48);
        let mut x = vec![0.0f32; n * d];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut prev = f64::INFINITY;
        for r in [1usize, 4, 16] {
            let lr = power_iter_lowrank(&x, n, d, r, 4, &mut rng);
            let err = fro_dist(&x, lr.to_dense().data());
            assert!(err < prev, "r={r}: {err} !< {prev}");
            prev = err;
        }
    }

    #[test]
    fn matches_exact_top_r_energy() {
        // Power iteration must capture nearly all the energy the exact top-r
        // SVD captures, on a matrix with decaying spectrum.
        let mut rng = Rng::new(33);
        let (n, d) = (40, 24);
        // Sum of rank-1 terms with geometric decay.
        let mut x = vec![0.0f32; n * d];
        for k in 0..8 {
            let term = rank_k(&mut rng, n, d, 1);
            let w = 0.5f32.powi(k);
            for (xi, ti) in x.iter_mut().zip(&term) {
                *xi += w * ti;
            }
        }
        let r = 3;
        let lr = power_iter_lowrank(&x, n, d, r, 6, &mut rng);
        let resid = fro_dist(&x, lr.to_dense().data());
        let exact_sv = crate::gear::error::singular_values(&x, n, d);
        let exact_resid: f64 = exact_sv[r..].iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!(
            resid <= exact_resid * 1.25 + 1e-6,
            "power-iter residual {resid} vs exact {exact_resid}"
        );
    }

    #[test]
    fn headwise_matches_concat_of_heads() {
        let mut rng = Rng::new(34);
        let (n, d, heads) = (20, 16, 4);
        let mut x = vec![0.0f32; n * d];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let hw = HeadwiseLowRank::decompose(&x, n, d, heads, 2, 4, &mut rng);
        assert_eq!(hw.heads.len(), heads);
        let mut full = vec![0.0f32; n * d];
        hw.add_into(&mut full);
        let mut by_rows = vec![0.0f32; n * d];
        for i in 0..n {
            hw.add_row_into(i, &mut by_rows[i * d..(i + 1) * d]);
        }
        for (a, b) in full.iter().zip(&by_rows) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn prop_approximation_never_worse_than_zero() {
        // ||X - AB^T|| <= ||X|| (the solver must at least not anti-fit) on
        // matrices with a planted low-rank component.
        prop::check(
            |r| {
                let n = 8 + r.next_below(24) as usize;
                let d = 8 + r.next_below(24) as usize;
                let planted = rank_k(&mut r.split(), n, d, 2);
                let mut noise = vec![0.0f32; n * d];
                r.fill_normal(&mut noise, 0.0, 0.05);
                let x: Vec<f32> = planted.iter().zip(&noise).map(|(a, b)| a + b).collect();
                (x, n, d, r.split())
            },
            |(x, n, d, rng)| {
                let mut rng = rng.clone();
                let lr = power_iter_lowrank(x, *n, *d, 2, 4, &mut rng);
                let err = fro_dist(x, lr.to_dense().data());
                let norm = fro_norm(x);
                if err <= norm * 0.5 {
                    Ok(())
                } else {
                    Err(format!("err {err} > 0.5 * ||X|| {norm}"))
                }
            },
        );
    }

    #[test]
    fn nbytes_is_fp16() {
        let lr = LowRank { n: 10, d: 6, r: 2, a: vec![0.0; 20], b: vec![0.0; 12] };
        assert_eq!(lr.nbytes(), (20 + 12) * 2);
    }
}
