//! Fused attention against a compressed KV matrix — the decode hot path.
//!
//! This is the Rust analogue of the paper's fused dequantization-matmul CUDA
//! kernel plus its factored low-rank forward: the low-rank component is
//! never materialized. For scores, `qᵀ(A Bᵀ)ᵀ` is computed as
//! `(Bᵀ q) · A[t]` (down-projection first — §4 "Implementation
//! optimization"); for the value side, `pᵀ(A Bᵀ)` is `(pᵀ A) Bᵀ`. Both cost
//! O((n + d_H)·r) per head instead of O(n·d_H·r).
//!
//! All kernels operate through a caller-owned [`SegScratch`]: the dequant
//! row buffer, the per-column scale/zero gather plan, and the rank-sized
//! down-projection `Bᵀq` each live in the scratch and are computed once per
//! segment per call — the batch executor hands every worker one scratch, so
//! no allocation happens in the sweep hot loop. The legacy `*_into` entry
//! points (tests, analysis tools, benches) share one lazily-initialized
//! per-thread scratch instead of allocating a throwaway per call.
//!
//! Layout convention: multi-head scores/probabilities are stored row-major
//! per token: `s[t * n_heads + h]`.

use super::compose::CompressedMatrix;
use super::quant::{Axis, RowDequantPlan};
use crate::tensor::ops::dot;

/// Per-segment kernel scratch: reusable buffers for the fused score /
/// weighted-sum kernels. One instance per executor worker; sized lazily to
/// the largest segment it has seen.
#[derive(Debug, Default, Clone)]
pub struct SegScratch {
    /// Dequantized-row staging buffer (`cols` long while in use).
    pub row: Vec<f32>,
    /// Low-rank down-projection `Bᵀq` / up-projection `pᵀA` (`r` long).
    pub w: Vec<f32>,
    /// Scale/zero gather plan for Col-axis quantization schemes.
    pub plan: RowDequantPlan,
}

/// Grow `buf` to at least `n` and return the `n`-prefix.
#[inline]
fn prep(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

std::thread_local! {
    /// Shared scratch for the legacy non-`_scratch` entry points: one
    /// lazily-initialized per-thread instance (buffers grow to the largest
    /// segment seen) instead of a throwaway allocation per call. The hot
    /// path never touches this — executor workers pass their pinned
    /// scratch to the `_scratch` forms directly.
    static LEGACY_SCRATCH: std::cell::RefCell<SegScratch> =
        std::cell::RefCell::new(SegScratch::default());
}

impl CompressedMatrix {
    /// Accumulate attention scores of query `q` (d-dim, heads concatenated)
    /// against every stored token: `out[t*H + h] += scale · q_h · K[t]_h`.
    ///
    /// `out` must hold `rows * n_heads` values (pre-zeroed by the caller).
    pub fn scores_into(&self, q: &[f32], n_heads: usize, scale: f32, out: &mut [f32]) {
        LEGACY_SCRATCH.with(|s| {
            self.scores_into_scratch(q, n_heads, scale, &mut s.borrow_mut(), out)
        });
    }

    /// Scratch-reusing form of [`Self::scores_into`] — the batched decode
    /// hot path. `scratch` may be shared across segments and calls.
    pub fn scores_into_scratch(
        &self,
        q: &[f32],
        n_heads: usize,
        scale: f32,
        scratch: &mut SegScratch,
        out: &mut [f32],
    ) {
        let (n, d) = (self.rows, self.cols);
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(out.len(), n * n_heads);
        debug_assert_eq!(d % n_heads, 0);
        let dh = d / n_heads;

        if let Some(dense) = &self.dense {
            for t in 0..n {
                let row = &dense[t * d..(t + 1) * d];
                for h in 0..n_heads {
                    out[t * n_heads + h] +=
                        scale * dot(&q[h * dh..(h + 1) * dh], &row[h * dh..(h + 1) * dh]);
                }
            }
            return;
        }

        // Quantized backbone: dequantize a row at a time into scratch.
        if let Some(qm) = &self.quant {
            let t0 = std::time::Instant::now();
            scratch.plan.prepare(d);
            let row = prep(&mut scratch.row, d);
            for t in 0..n {
                qm.dequantize_row_planned(t, &mut scratch.plan, row);
                for h in 0..n_heads {
                    out[t * n_heads + h] +=
                        scale * dot(&q[h * dh..(h + 1) * dh], &row[h * dh..(h + 1) * dh]);
                }
            }
            super::record_phase("quant", t0.elapsed());
        }

        // Sparse outliers: only touched coordinates contribute.
        if let Some(sp) = &self.sparse {
            let t0 = std::time::Instant::now();
            for (k, &(i, j)) in sp.idx.iter().enumerate() {
                let (t, c) = (i as usize, j as usize);
                let h = c / dh;
                out[t * n_heads + h] += scale * q[c] * sp.val[k];
            }
            super::record_phase("sparse", t0.elapsed());
        }

        // Low-rank, factored: per head w = B_hᵀ q_h (r), then out += w·A_h[t].
        // The down-projection is computed once per (segment, head) into the
        // shared scratch instead of a fresh allocation each time.
        if let Some(lrh) = &self.lowrank {
            let t0 = std::time::Instant::now();
            for (h, lr) in lrh.heads.iter().enumerate() {
                let qh = &q[h * dh..(h + 1) * dh];
                let r = lr.r;
                let w = prep(&mut scratch.w, r);
                w.fill(0.0);
                for j in 0..dh {
                    let brow = &lr.b[j * r..(j + 1) * r];
                    let qj = qh[j];
                    if qj == 0.0 {
                        continue;
                    }
                    for k in 0..r {
                        w[k] += qj * brow[k];
                    }
                }
                for t in 0..n {
                    out[t * n_heads + h] += scale * dot(w, &lr.a[t * r..(t + 1) * r]);
                }
            }
            super::record_phase("lowrank", t0.elapsed());
        }
    }

    /// Accumulate the attention-weighted value sum:
    /// `out[h*dh + c] += Σ_t p[t*H + h] · V[t]_{h,c}`.
    pub fn weighted_sum_into(&self, probs: &[f32], n_heads: usize, out: &mut [f32]) {
        LEGACY_SCRATCH.with(|s| {
            self.weighted_sum_into_scratch(probs, n_heads, &mut s.borrow_mut(), out)
        });
    }

    /// Scratch-reusing form of [`Self::weighted_sum_into`].
    pub fn weighted_sum_into_scratch(
        &self,
        probs: &[f32],
        n_heads: usize,
        scratch: &mut SegScratch,
        out: &mut [f32],
    ) {
        let (n, d) = (self.rows, self.cols);
        debug_assert_eq!(probs.len(), n * n_heads);
        debug_assert_eq!(out.len(), d);
        let dh = d / n_heads;

        if let Some(dense) = &self.dense {
            for t in 0..n {
                let row = &dense[t * d..(t + 1) * d];
                for h in 0..n_heads {
                    let p = probs[t * n_heads + h];
                    if p == 0.0 {
                        continue;
                    }
                    crate::tensor::ops::axpy(
                        p,
                        &row[h * dh..(h + 1) * dh],
                        &mut out[h * dh..(h + 1) * dh],
                    );
                }
            }
            return;
        }

        if let Some(qm) = &self.quant {
            let t0 = std::time::Instant::now();
            scratch.plan.prepare(d);
            let row = prep(&mut scratch.row, d);
            for t in 0..n {
                qm.dequantize_row_planned(t, &mut scratch.plan, row);
                for h in 0..n_heads {
                    let p = probs[t * n_heads + h];
                    crate::tensor::ops::axpy(
                        p,
                        &row[h * dh..(h + 1) * dh],
                        &mut out[h * dh..(h + 1) * dh],
                    );
                }
            }
            super::record_phase("quant", t0.elapsed());
        }

        if let Some(sp) = &self.sparse {
            let t0 = std::time::Instant::now();
            for (k, &(i, j)) in sp.idx.iter().enumerate() {
                let (t, c) = (i as usize, j as usize);
                let h = c / dh;
                out[c] += probs[t * n_heads + h] * sp.val[k];
            }
            super::record_phase("sparse", t0.elapsed());
        }

        // Low-rank, factored: per head w = Σ_t p[t,h] A_h[t] (r), out_h += B_h w.
        if let Some(lrh) = &self.lowrank {
            let t0 = std::time::Instant::now();
            for (h, lr) in lrh.heads.iter().enumerate() {
                let r = lr.r;
                let w = prep(&mut scratch.w, r);
                w.fill(0.0);
                for t in 0..n {
                    let p = probs[t * n_heads + h];
                    if p == 0.0 {
                        continue;
                    }
                    crate::tensor::ops::axpy(p, &lr.a[t * r..(t + 1) * r], w);
                }
                let oh = &mut out[h * dh..(h + 1) * dh];
                for j in 0..dh {
                    oh[j] += dot(w, &lr.b[j * r..(j + 1) * r]);
                }
            }
            super::record_phase("lowrank", t0.elapsed());
        }
    }
}

/// Reference (unfused) score computation used by tests: reconstruct the full
/// matrix, then do dense per-head dots.
pub fn scores_reference(
    cm: &CompressedMatrix,
    q: &[f32],
    n_heads: usize,
    scale: f32,
) -> Vec<f32> {
    let full = cm.reconstruct();
    let (n, d) = (cm.rows, cm.cols);
    let dh = d / n_heads;
    let mut out = vec![0.0f32; n * n_heads];
    for t in 0..n {
        for h in 0..n_heads {
            out[t * n_heads + h] =
                scale * dot(&q[h * dh..(h + 1) * dh], &full.row(t)[h * dh..(h + 1) * dh]);
        }
    }
    out
}

/// Reference weighted sum used by tests.
pub fn weighted_sum_reference(cm: &CompressedMatrix, probs: &[f32], n_heads: usize) -> Vec<f32> {
    let full = cm.reconstruct();
    let (n, d) = (cm.rows, cm.cols);
    let dh = d / n_heads;
    let mut out = vec![0.0f32; d];
    for t in 0..n {
        for h in 0..n_heads {
            let p = probs[t * n_heads + h];
            for c in 0..dh {
                out[h * dh + c] += p * full.row(t)[h * dh + c];
            }
        }
    }
    out
}

/// Sanity guard used by caches: sparse row/col bounds must fit the matrix.
pub fn validate_sparse_bounds(cm: &CompressedMatrix) -> bool {
    match &cm.sparse {
        None => true,
        Some(sp) => {
            debug_assert!(matches!(sp.axis, Axis::Row | Axis::Col));
            sp.idx
                .iter()
                .all(|&(i, j)| (i as usize) < cm.rows && (j as usize) < cm.cols)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gear::compose::{compress, Backbone, GearConfig, Method};
    use crate::gear::KvKind;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn methods() -> Vec<Method> {
        vec![
            Method::Fp16,
            Method::QuantOnly { bits: 4, backbone: Backbone::Kcvt },
            Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(16) },
            Method::gear_default(2),
            Method::gear_l_default(4),
            Method::OutlierAware { bits: 2, backbone: Backbone::Kivi(16), s: 0.04 },
            Method::LowRankOnly { r: 3 },
            Method::SparseOnly { s: 0.06 },
        ]
    }

    #[test]
    fn fused_scores_match_reference() {
        let mut rng = Rng::new(70);
        let (n, d, h) = (48, 32, 4);
        let x = Tensor::randn(&[n, d], &mut rng, 1.0);
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for m in methods() {
            let cm = compress(&x, KvKind::Key, &GearConfig::new(m, h));
            assert!(validate_sparse_bounds(&cm));
            let mut fused = vec![0.0f32; n * h];
            cm.scores_into(&q, h, 0.25, &mut fused);
            let reference = scores_reference(&cm, &q, h, 0.25);
            for (a, b) in fused.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-3, "{m:?}: fused {a} vs ref {b}");
            }
        }
    }

    #[test]
    fn fused_weighted_sum_matches_reference() {
        let mut rng = Rng::new(71);
        let (n, d, h) = (40, 32, 4);
        let x = Tensor::randn(&[n, d], &mut rng, 1.0);
        let mut probs = vec![0.0f32; n * h];
        for hh in 0..h {
            // random softmax-ish distribution per head
            let mut s = 0.0f32;
            for t in 0..n {
                let v = rng.next_f32();
                probs[t * h + hh] = v;
                s += v;
            }
            for t in 0..n {
                probs[t * h + hh] /= s;
            }
        }
        for m in methods() {
            let cm = compress(&x, KvKind::Value, &GearConfig::new(m, h));
            let mut fused = vec![0.0f32; d];
            cm.weighted_sum_into(&probs, h, &mut fused);
            let reference = weighted_sum_reference(&cm, &probs, h);
            for (a, b) in fused.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-3, "{m:?}: fused {a} vs ref {b}");
            }
        }
    }

    #[test]
    fn scores_accumulate_not_overwrite() {
        let mut rng = Rng::new(72);
        let x = Tensor::randn(&[8, 16], &mut rng, 1.0);
        let q: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let cm = compress(&x, KvKind::Key, &GearConfig::new(Method::Fp16, 2));
        let mut out = vec![1.0f32; 8 * 2];
        cm.scores_into(&q, 2, 1.0, &mut out);
        let reference = scores_reference(&cm, &q, 2, 1.0);
        for (o, r) in out.iter().zip(&reference) {
            assert!((o - (r + 1.0)).abs() < 1e-4);
        }
    }
}
