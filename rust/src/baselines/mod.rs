//! Baseline compression methods the paper compares against.
//!
//! Quantization baselines (per-token group-wise, KIVI, KCVT) live in
//! [`crate::gear::quant`] since GEAR composes over them; this module holds
//! the structurally-different baseline: H₂O token dropping.

pub mod h2o;
