//! H₂O (Heavy-Hitter Oracle) token-dropping baseline (Zhang et al., 2023).
//!
//! Keeps the KV cache at `keep` fraction of the tokens seen so far: the most
//! recent `recent` tokens are always retained (the "local" window), and the
//! remaining slots go to *heavy hitters* — tokens with the highest
//! accumulated attention scores. On every `attend`, per-token attention
//! probabilities (summed over heads) are added to the running score; when
//! the cache exceeds its budget, the lowest-scoring non-recent token is
//! evicted. Storage is FP16-accounted dense, like the paper's H₂O setup.

use crate::gear::size::SizeBreakdown;
use crate::kvcache::dense::softmax_heads;
use crate::kvcache::{AttendScratch, LayerKv};
use crate::tensor::ops::dot;
use crate::tensor::Tensor;
use crate::util::f16::to_f16_precision;

pub struct H2oLayerKv {
    d: usize,
    keep: f64,
    recent: usize,
    /// Retained rows (K and V index-aligned), in original order.
    k: Vec<f32>,
    v: Vec<f32>,
    /// Accumulated attention mass per retained token.
    acc: Vec<f32>,
    /// Total tokens ever seen (drives the budget).
    seen: usize,
}

impl H2oLayerKv {
    pub fn new(d: usize, keep: f64, recent: usize) -> Self {
        assert!((0.0..=1.0).contains(&keep));
        H2oLayerKv {
            d,
            keep,
            recent: recent.max(1),
            k: Vec::new(),
            v: Vec::new(),
            acc: Vec::new(),
            seen: 0,
        }
    }

    fn n(&self) -> usize {
        self.acc.len()
    }

    fn budget(&self) -> usize {
        ((self.seen as f64 * self.keep).ceil() as usize).max(self.recent)
    }

    fn push(&mut self, k: &[f32], v: &[f32]) {
        self.k.extend(k.iter().map(|&x| to_f16_precision(x)));
        self.v.extend(v.iter().map(|&x| to_f16_precision(x)));
        self.acc.push(0.0);
        self.seen += 1;
    }

    fn evict_to_budget(&mut self) {
        while self.n() > self.budget() {
            // Lowest accumulated score among non-recent tokens.
            let cutoff = self.n().saturating_sub(self.recent);
            let Some((victim, _)) = self.acc[..cutoff]
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
            else {
                break; // everything is within the recent window
            };
            let d = self.d;
            self.k.drain(victim * d..(victim + 1) * d);
            self.v.drain(victim * d..(victim + 1) * d);
            self.acc.remove(victim);
        }
    }

    /// Tokens dropped so far.
    pub fn dropped(&self) -> usize {
        self.seen - self.n()
    }
}

impl LayerKv for H2oLayerKv {
    fn ingest_prefill(&mut self, k: Tensor, v: Tensor, attn_mass: Option<&[f32]>) {
        assert_eq!(k.cols(), self.d);
        let n0 = self.n();
        for i in 0..k.rows() {
            self.push(k.row(i), v.row(i));
        }
        // Seed heavy-hitter statistics from the prefill attention mass (the
        // accumulated attention each prompt token received), then prune the
        // prompt to budget — H₂O's oracle over the prompt.
        if let Some(mass) = attn_mass {
            assert_eq!(mass.len(), k.rows());
            for (i, &m) in mass.iter().enumerate() {
                self.acc[n0 + i] += m;
            }
        }
        self.evict_to_budget();
    }

    fn append(&mut self, k: &[f32], v: &[f32]) {
        self.push(k, v);
        self.evict_to_budget();
    }

    fn len(&self) -> usize {
        self.n()
    }

    fn attend_scratch(
        &mut self,
        q: &[f32],
        n_heads: usize,
        scratch: &mut AttendScratch,
        out: &mut [f32],
    ) {
        let (n, d) = (self.n(), self.d);
        debug_assert_eq!(out.len(), d);
        let dh = d / n_heads;
        let scale = 1.0 / (dh as f32).sqrt();

        let scores = &mut scratch.scores;
        scores.clear();
        scores.resize(n * n_heads, 0.0);
        for t in 0..n {
            let krow = &self.k[t * d..(t + 1) * d];
            for h in 0..n_heads {
                scores[t * n_heads + h] =
                    scale * dot(&q[h * dh..(h + 1) * dh], &krow[h * dh..(h + 1) * dh]);
            }
        }
        softmax_heads(scores, n, n_heads);

        out.fill(0.0);
        for t in 0..n {
            let vrow = &self.v[t * d..(t + 1) * d];
            let mut mass = 0.0f32;
            for h in 0..n_heads {
                let p = scores[t * n_heads + h];
                mass += p;
                let seg = h * dh..(h + 1) * dh;
                crate::tensor::ops::axpy(p, &vrow[seg.clone()], &mut out[seg]);
            }
            // Heavy-hitter statistic: accumulated attention mass.
            self.acc[t] += mass;
        }
    }

    fn nbytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 2 + self.acc.len() * 4
    }

    fn step_growth_bound(&self) -> usize {
        // K + V rows at FP16 plus the f32 score slot; eviction only shrinks.
        4 * self.d + 4
    }

    fn breakdown(&self) -> SizeBreakdown {
        SizeBreakdown {
            dense_bytes: (self.k.len() + self.v.len()) * 2,
            meta_bytes: self.acc.len() * 4,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn respects_budget() {
        let mut rng = Rng::new(100);
        let d = 8;
        let mut c = H2oLayerKv::new(d, 0.5, 2);
        let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for _ in 0..40 {
            c.append(&row, &row);
            let mut out = vec![0.0; d];
            c.attend(&row, 2, &mut out);
        }
        assert_eq!(c.len(), 20); // ceil(40 * 0.5)
        assert_eq!(c.dropped(), 20);
    }

    #[test]
    fn keeps_heavy_hitters() {
        let d = 4;
        let mut c = H2oLayerKv::new(d, 0.7, 2);
        // Token 0: key strongly aligned with future queries (heavy hitter).
        c.append(&[10.0, 10.0, 10.0, 10.0], &[1.0; 4]);
        let mut out = vec![0.0; d];
        c.attend(&[5.0, 5.0, 5.0, 5.0], 1, &mut out);
        // Fillers orthogonal to the query; attend after each so scores
        // accumulate (as they do in real decoding).
        for _ in 0..9 {
            c.append(&[0.0, 0.0, 0.0, 0.0], &[0.0; 4]);
            c.attend(&[5.0, 5.0, 5.0, 5.0], 1, &mut out);
        }
        // Budget = ceil(10 * 0.7) = 7: three fillers evicted, the heavy
        // hitter (highest accumulated attention) must have survived.
        assert_eq!(c.len(), 7);
        assert_eq!(c.dropped(), 3);
        let has_heavy = (0..c.len()).any(|t| c.k[t * d] > 5.0);
        assert!(has_heavy, "heavy hitter was evicted");
    }

    #[test]
    fn keep_one_drops_nothing() {
        let d = 4;
        let mut c = H2oLayerKv::new(d, 1.0, 1);
        for _ in 0..10 {
            c.append(&[1.0; 4], &[1.0; 4]);
        }
        assert_eq!(c.len(), 10);
        assert_eq!(c.dropped(), 0);
    }

    #[test]
    fn prefill_prunes_to_budget() {
        let mut rng = Rng::new(101);
        let d = 8;
        let k = Tensor::randn(&[20, d], &mut rng, 1.0);
        let v = Tensor::randn(&[20, d], &mut rng, 1.0);
        let mut c = H2oLayerKv::new(d, 0.25, 2);
        c.ingest_prefill(k, v, None);
        assert_eq!(c.len(), 5); // ceil(20 * 0.25)
    }

    #[test]
    fn attend_output_finite() {
        let mut rng = Rng::new(102);
        let d = 8;
        let mut c = H2oLayerKv::new(d, 0.5, 2);
        for _ in 0..12 {
            let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            c.append(&row, &row);
            let mut out = vec![0.0; d];
            c.attend(&row, 2, &mut out);
            assert!(out.iter().all(|x| x.is_finite()));
        }
    }
}
