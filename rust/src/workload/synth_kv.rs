//! Synthetic KV-cache matrices with realistic entry statistics.
//!
//! KIVI/KVQuant (and §2 of the GEAR paper) observe that Key caches have a
//! few *fixed channels* with very large magnitudes, while Value caches are
//! closer to i.i.d. with scattered outliers. The generator reproduces both
//! regimes so the error experiments (Fig 1a / 2a / 2b) exercise the same
//! structure the paper measured on LLaMA KV tensors, plus a coherent
//! low-rank component (token vectors share context) that gives residuals
//! their fast-decaying spectrum.

use crate::tensor::ops::matmul_into;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Parameters of the synthetic KV distribution.
#[derive(Debug, Clone, Copy)]
pub struct SynthKvParams {
    /// Log-normal sigma of per-channel scales (Key regime; 0 disables).
    pub channel_tail: f32,
    /// Probability an entry is an outlier.
    pub outlier_prob: f64,
    /// Outlier magnitude multiplier.
    pub outlier_mult: f32,
    /// Rank of the shared coherent component (0 disables).
    pub coherent_rank: usize,
    /// Relative weight of the coherent component.
    pub coherent_weight: f32,
}

impl SynthKvParams {
    /// Key-cache regime: strong fixed-channel structure.
    pub fn key() -> Self {
        SynthKvParams {
            channel_tail: 1.0,
            outlier_prob: 0.01,
            outlier_mult: 8.0,
            coherent_rank: 4,
            coherent_weight: 1.5,
        }
    }

    /// Value-cache regime: flatter channels, scattered outliers.
    pub fn value() -> Self {
        SynthKvParams {
            channel_tail: 0.3,
            outlier_prob: 0.02,
            outlier_mult: 6.0,
            coherent_rank: 2,
            coherent_weight: 0.8,
        }
    }
}

/// Generate an n×d KV-like matrix.
pub fn generate(rng: &mut Rng, n: usize, d: usize, p: &SynthKvParams) -> Tensor {
    let mut x = Tensor::zeros(&[n, d]);

    // Per-channel log-normal scales (fixed across tokens — the Key pattern).
    let mut chan_scale = vec![1.0f32; d];
    if p.channel_tail > 0.0 {
        for s in chan_scale.iter_mut() {
            *s = (rng.normal_f32() * p.channel_tail).exp();
        }
    }

    for i in 0..n {
        for j in 0..d {
            let mut v = rng.normal_f32() * chan_scale[j];
            if rng.next_f64() < p.outlier_prob {
                v *= p.outlier_mult;
            }
            x.data_mut()[i * d + j] = v;
        }
    }

    // Shared coherent (low-rank) component.
    if p.coherent_rank > 0 && p.coherent_weight > 0.0 {
        let r = p.coherent_rank.min(n).min(d);
        let mut u = vec![0.0f32; n * r];
        let mut vt = vec![0.0f32; r * d];
        rng.fill_normal(&mut u, 0.0, 1.0);
        rng.fill_normal(&mut vt, 0.0, 1.0);
        let mut low = vec![0.0f32; n * d];
        matmul_into(&u, &vt, n, r, d, &mut low);
        let w = p.coherent_weight / (r as f32).sqrt();
        for (xi, li) in x.data_mut().iter_mut().zip(&low) {
            *xi += w * li;
        }
    }
    x
}

/// Generate a (K, V) pair with their respective regimes.
pub fn generate_kv(rng: &mut Rng, n: usize, d: usize) -> (Tensor, Tensor) {
    (generate(rng, n, d, &SynthKvParams::key()), generate(rng, n, d, &SynthKvParams::value()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gear::error::singular_values;

    #[test]
    fn key_channels_are_heavy_tailed() {
        let mut rng = Rng::new(110);
        let x = generate(&mut rng, 256, 64, &SynthKvParams::key());
        // Per-channel std devs should span a wide range.
        let mut stds: Vec<f32> = (0..64)
            .map(|j| {
                let mut s = 0.0f32;
                for i in 0..256 {
                    s += x.data()[i * 64 + j].powi(2);
                }
                (s / 256.0).sqrt()
            })
            .collect();
        stds.sort_by(f32::total_cmp);
        let ratio = stds[63] / stds[0].max(1e-6);
        assert!(ratio > 5.0, "channel scale spread {ratio} too flat for Key regime");
    }

    #[test]
    fn value_regime_flatter_than_key() {
        let mut rng = Rng::new(111);
        let spread = |p: &SynthKvParams, rng: &mut Rng| {
            let x = generate(rng, 256, 64, p);
            let mut stds: Vec<f32> = (0..64)
                .map(|j| {
                    let mut s = 0.0f32;
                    for i in 0..256 {
                        s += x.data()[i * 64 + j].powi(2);
                    }
                    (s / 256.0).sqrt()
                })
                .collect();
            stds.sort_by(f32::total_cmp);
            stds[63] / stds[0].max(1e-6)
        };
        let key = spread(&SynthKvParams::key(), &mut rng);
        let value = spread(&SynthKvParams::value(), &mut rng);
        assert!(key > value, "key spread {key} !> value spread {value}");
    }

    #[test]
    fn coherent_component_gives_decaying_spectrum() {
        // Fig 2b precondition: top singular values dominate.
        let mut rng = Rng::new(112);
        let x = generate(&mut rng, 128, 32, &SynthKvParams::key());
        let sv = singular_values(x.data(), 128, 32);
        let top4: f64 = sv[..4].iter().map(|s| s * s).sum();
        let total: f64 = sv.iter().map(|s| s * s).sum();
        assert!(top4 / total > 0.3, "top-4 energy {} too flat", top4 / total);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&mut Rng::new(7), 16, 8, &SynthKvParams::key());
        let b = generate(&mut Rng::new(7), 16, 8, &SynthKvParams::key());
        assert_eq!(a, b);
    }
}
