//! Synthetic evaluation tasks (the GSM8k-CoT / LongBench substitutes).
//!
//! **chain-arith** — multi-step modular arithmetic with chain-of-thought:
//!
//! ```text
//! prompt:      a=3;b=7;c=a+b;d=c*b;d?\n            (plus few-shot examples)
//! completion:  a=3;b=7;c=0;d=0;>0\n
//! ```
//!
//! The completion restates every variable's resolved value (mod 10) before
//! the final `>answer`. Each step conditions on previously *generated*
//! values, so KV-cache approximation error compounds across the generation
//! exactly as in the paper's CoT analysis (§1, Fig 1b).
//!
//! **kv-recall** — a key–value store lookup with a short answer:
//!
//! ```text
//! prompt:      f4=2;k1=9;...;k1?\n
//! completion:  >9\n
//! ```
//!
//! The answer depends on one prompt location — the easy-task regime
//! (Table 2) where even aggressive compression is near-lossless.
//!
//! The Python trainer (`python/compile/train.py`) generates the same
//! formats; keep them in lockstep.

use crate::util::rng::Rng;

/// Task family and difficulty knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Multi-step CoT arithmetic: `steps` assignments (≥ 2), `shots`
    /// solved examples prepended to the prompt.
    ChainArith { steps: usize, shots: usize },
    /// Key–value recall over `pairs` bindings.
    KvRecall { pairs: usize },
}

impl Task {
    /// The paper-analogous default hard task (GSM8k-CoT stand-in).
    pub fn hard() -> Task {
        Task::ChainArith { steps: 6, shots: 3 }
    }

    /// The paper-analogous default easy task (LongBench stand-in).
    pub fn easy() -> Task {
        Task::KvRecall { pairs: 24 }
    }

    pub fn label(&self) -> String {
        match self {
            Task::ChainArith { steps, shots } => format!("chain-arith(s={steps},k={shots})"),
            Task::KvRecall { pairs } => format!("kv-recall(p={pairs})"),
        }
    }
}

/// One evaluation instance.
#[derive(Debug, Clone)]
pub struct TaskInstance {
    /// Full prompt text (few-shot examples + test query), ends with '\n'.
    pub prompt: String,
    /// Gold completion (CoT line or answer line), ends with '\n'.
    pub completion: String,
    /// Ground-truth final answer digit.
    pub answer: char,
}

/// A generated program: variable names and their resolved values.
struct Program {
    text: String,
    cot: String,
    answer: char,
}

const VARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz";

fn gen_program(rng: &mut Rng, steps: usize) -> Program {
    let steps = steps.clamp(2, 24);
    let mut names: Vec<u8> = VARS.to_vec();
    rng.shuffle(&mut names);
    let names = &names[..steps];
    let mut values: Vec<u32> = Vec::with_capacity(steps);
    let mut text = String::new();
    let mut cot = String::new();

    for (i, &name) in names.iter().enumerate() {
        let name = name as char;
        if i < 2 {
            // Seed assignments with literals.
            let v = rng.next_below(10) as u32;
            values.push(v);
            text.push_str(&format!("{name}={v};"));
        } else {
            // Combine two earlier variables.
            let a = rng.next_below(i as u64) as usize;
            let mut b = rng.next_below(i as u64) as usize;
            if b == a {
                b = (b + 1) % i;
            }
            let op = *rng.choose(&[b'+', b'-', b'*']) as char;
            let v = match op {
                '+' => (values[a] + values[b]) % 10,
                '-' => (10 + values[a] - values[b]) % 10,
                _ => (values[a] * values[b]) % 10,
            };
            values.push(v);
            text.push_str(&format!(
                "{name}={}{op}{};",
                names[a] as char, names[b] as char
            ));
        }
        cot.push_str(&format!("{name}={};", values[i]));
    }
    let answer = char::from_digit(values[steps - 1], 10).unwrap();
    // Query the final variable.
    text.push_str(&format!("{}?", names[steps - 1] as char));
    cot.push_str(&format!(">{answer}"));
    Program { text, cot, answer }
}

/// Generate one instance of `task`.
pub fn generate_instance(task: Task, rng: &mut Rng) -> TaskInstance {
    match task {
        Task::ChainArith { steps, shots } => {
            let mut prompt = String::new();
            for _ in 0..shots {
                let ex = gen_program(rng, steps);
                prompt.push_str(&ex.text);
                prompt.push('\n');
                prompt.push_str(&ex.cot);
                prompt.push('\n');
            }
            let test = gen_program(rng, steps);
            prompt.push_str(&test.text);
            prompt.push('\n');
            TaskInstance {
                prompt,
                completion: format!("{}\n", test.cot),
                answer: test.answer,
            }
        }
        Task::KvRecall { pairs } => {
            let pairs = pairs.clamp(2, 200);
            // Distinct two-char keys: letter + digit.
            let mut keys: Vec<String> = Vec::with_capacity(pairs);
            let mut vals: Vec<u32> = Vec::with_capacity(pairs);
            let mut used = std::collections::HashSet::new();
            while keys.len() < pairs {
                let k = format!(
                    "{}{}",
                    VARS[rng.next_below(26) as usize] as char,
                    rng.next_below(10)
                );
                if used.insert(k.clone()) {
                    keys.push(k);
                    vals.push(rng.next_below(10) as u32);
                }
            }
            let mut prompt = String::new();
            for (k, v) in keys.iter().zip(&vals) {
                prompt.push_str(&format!("{k}={v};"));
            }
            let qi = rng.next_below(pairs as u64) as usize;
            prompt.push_str(&format!("{}?\n", keys[qi]));
            let answer = char::from_digit(vals[qi], 10).unwrap();
            TaskInstance { prompt, completion: format!(">{answer}\n"), answer }
        }
    }
}

/// Generate a deterministic evaluation set.
pub fn generate_set(task: Task, n: usize, seed: u64) -> Vec<TaskInstance> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| generate_instance(task, &mut rng)).collect()
}

/// Score a model generation against an instance: the answer is the first
/// character after the last `>` in the output.
pub fn score(output: &str, inst: &TaskInstance) -> bool {
    extract_answer(output).map(|a| a == inst.answer).unwrap_or(false)
}

/// Extract the final `>digit` answer from a generation.
pub fn extract_answer(output: &str) -> Option<char> {
    let pos = output.rfind('>')?;
    output[pos + 1..].chars().next().filter(|c| c.is_ascii_digit())
}

/// Exact-match score on the full CoT line (strict metric, used by
/// ablations to show *where* generations diverge).
pub fn score_cot(output: &str, inst: &TaskInstance) -> bool {
    output.trim_end_matches('\n') == inst.completion.trim_end_matches('\n')
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Tokenizer;

    /// Evaluate a chain-arith program text independently (test oracle).
    fn eval_program(text: &str) -> Option<u32> {
        let mut env = std::collections::HashMap::new();
        let text = text.strip_suffix('?')?;
        let mut query = ' ';
        for stmt in text.split(';') {
            if stmt.len() == 1 {
                query = stmt.chars().next()?;
                continue;
            }
            let (lhs, rhs) = stmt.split_once('=')?;
            let lhs = lhs.chars().next()?;
            let v = if rhs.len() == 1 {
                rhs.parse::<u32>().ok().or_else(|| env.get(&rhs.chars().next()?).copied())?
            } else {
                let mut cs = rhs.chars();
                let a = *env.get(&cs.next()?)?;
                let op = cs.next()?;
                let b = *env.get(&cs.next()?)?;
                match op {
                    '+' => (a + b) % 10,
                    '-' => (10 + a - b) % 10,
                    '*' => (a * b) % 10,
                    _ => return None,
                }
            };
            env.insert(lhs, v);
        }
        env.get(&query).copied()
    }

    #[test]
    fn chain_arith_answer_is_correct() {
        let mut rng = Rng::new(120);
        for _ in 0..50 {
            let inst = generate_instance(Task::ChainArith { steps: 5, shots: 0 }, &mut rng);
            let program = inst.prompt.trim_end_matches('\n').split('\n').last().unwrap();
            // Strip trailing "x?" into evaluable form.
            let truth = eval_program(program.trim_end_matches('\n')).expect("evaluable");
            assert_eq!(inst.answer, char::from_digit(truth, 10).unwrap(), "{program}");
        }
    }

    #[test]
    fn cot_ends_with_answer() {
        let mut rng = Rng::new(121);
        for _ in 0..20 {
            let inst = generate_instance(Task::hard(), &mut rng);
            assert!(inst.completion.contains('>'));
            assert_eq!(extract_answer(&inst.completion), Some(inst.answer));
        }
    }

    #[test]
    fn kv_recall_answer_matches_binding() {
        let mut rng = Rng::new(122);
        for _ in 0..50 {
            let inst = generate_instance(Task::KvRecall { pairs: 10 }, &mut rng);
            // Parse prompt: find the queried key and its binding.
            let prompt = inst.prompt.trim_end_matches('\n');
            let q = prompt.rsplit(';').next().unwrap().trim_end_matches('?');
            let binding = prompt
                .split(';')
                .find(|s| s.starts_with(&format!("{q}=")))
                .unwrap_or_else(|| panic!("binding for {q} in {prompt}"));
            assert_eq!(binding.chars().last().unwrap(), inst.answer);
        }
    }

    #[test]
    fn prompts_tokenize() {
        // Everything generated must be encodable by the model tokenizer.
        let t = Tokenizer::new();
        let mut rng = Rng::new(123);
        for task in [Task::hard(), Task::easy(), Task::ChainArith { steps: 10, shots: 5 }] {
            let inst = generate_instance(task, &mut rng);
            let ids = t.encode(&inst.prompt);
            assert!(!ids.is_empty());
            t.encode(&inst.completion);
        }
    }

    #[test]
    fn scoring() {
        let inst = TaskInstance {
            prompt: "x?".into(),
            completion: "a=1;>7\n".into(),
            answer: '7',
        };
        assert!(score("a=1;>7\n", &inst));
        assert!(score("garbage >7", &inst));
        assert!(!score(">3", &inst));
        assert!(!score("no answer", &inst));
        assert!(score_cot("a=1;>7", &inst));
        assert!(!score_cot("a=2;>7", &inst));
    }

    #[test]
    fn shots_lengthen_prompt() {
        let mut rng = Rng::new(124);
        let short = generate_instance(Task::ChainArith { steps: 5, shots: 0 }, &mut rng);
        let long = generate_instance(Task::ChainArith { steps: 5, shots: 4 }, &mut rng);
        assert!(long.prompt.len() > 3 * short.prompt.len());
    }

    #[test]
    fn set_is_deterministic() {
        let a = generate_set(Task::hard(), 5, 99);
        let b = generate_set(Task::hard(), 5, 99);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }
}
