//! Synthetic workloads standing in for the paper's evaluation suites
//! (DESIGN.md §3 documents the substitution argument).
//!
//! * [`tasks`] — **chain-arith** (hard, CoT-style multi-step reasoning ≈
//!   GSM8k/AQuA/BBH with CoT) and **kv-recall** (easy retrieval ≈
//!   LongBench / GSM8k 5-shot).
//! * [`synth_kv`] — synthetic KV matrices with the entry distribution the
//!   paper analyzes (heavy-tailed fixed channels in Keys, outliers), for
//!   the error experiments that don't need a model.

pub mod synth_kv;
pub mod tasks;
