//! Model hyperparameters and the character-level tokenizer.
//!
//! The vocabulary is shared verbatim with `python/compile/model.py`; both
//! sides derive token ids from [`VOCAB_CHARS`] by position, so changing the
//! string is a breaking format change for trained weights.

/// Characters the tokenizer knows, in id order after the specials.
pub const VOCAB_CHARS: &str = "0123456789abcdefghijklmnopqrstuvwxyz=+-*%;?> \n";

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const N_SPECIAL: u32 = 3;

/// Total vocabulary size (specials + characters).
pub const VOCAB_SIZE: usize = N_SPECIAL as usize + 46;

/// Model shape hyperparameters. `default()` matches the build-time trained
/// checkpoint in `artifacts/weights.bin`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { vocab: VOCAB_SIZE, d_model: 128, n_layers: 4, n_heads: 4, max_seq: 640 }
    }
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    pub fn mlp_dim(&self) -> usize {
        4 * self.d_model
    }

    /// FP16 bytes of an uncompressed KV cache holding `n` tokens (K + V
    /// across all layers) — the denominator of the paper's KV-size metric.
    pub fn fp16_kv_bytes(&self, n: usize) -> usize {
        self.n_layers * 2 * n * self.d_model * 2
    }
}

/// Character-level tokenizer over [`VOCAB_CHARS`].
#[derive(Debug, Clone)]
pub struct Tokenizer {
    char_to_id: [u32; 128],
    id_to_char: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut char_to_id = [u32::MAX; 128];
        let mut id_to_char = vec!['\0'; VOCAB_SIZE];
        for (i, c) in VOCAB_CHARS.chars().enumerate() {
            let id = N_SPECIAL + i as u32;
            char_to_id[c as usize] = id;
            id_to_char[id as usize] = c;
        }
        Tokenizer { char_to_id, id_to_char }
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }

    /// Encode text; unknown characters panic (workload generators only emit
    /// vocabulary characters).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.chars()
            .map(|c| {
                let id = self.char_to_id.get(c as usize).copied().unwrap_or(u32::MAX);
                assert!(id != u32::MAX, "character {c:?} not in vocabulary");
                id
            })
            .collect()
    }

    /// Encode with a leading BOS.
    pub fn encode_with_bos(&self, text: &str) -> Vec<u32> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        ids.push(BOS);
        ids.extend(self.encode(text));
        ids
    }

    /// Decode ids, skipping specials.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter(|&&id| id >= N_SPECIAL && (id as usize) < VOCAB_SIZE)
            .map(|&id| self.id_to_char[id as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_size_consistent() {
        assert_eq!(VOCAB_CHARS.chars().count(), VOCAB_SIZE - N_SPECIAL as usize);
        assert_eq!(VOCAB_SIZE, 49);
    }

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let s = "a=3;b=7;c=a+b;c?\n>0";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bos_prefix() {
        let t = Tokenizer::new();
        let ids = t.encode_with_bos("ab");
        assert_eq!(ids[0], BOS);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    #[should_panic(expected = "not in vocabulary")]
    fn unknown_char_panics() {
        Tokenizer::new().encode("A"); // uppercase not in vocab
    }

    #[test]
    fn ids_are_stable() {
        // Format compatibility with the Python side: '0' must be id 3.
        let t = Tokenizer::new();
        assert_eq!(t.encode("0"), vec![3]);
        assert_eq!(t.encode("9"), vec![12]);
        assert_eq!(t.encode("a"), vec![13]);
        assert_eq!(t.encode("\n"), vec![48]);
    }

    #[test]
    fn config_helpers() {
        let c = ModelConfig::default();
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.mlp_dim(), 512);
        assert_eq!(c.fp16_kv_bytes(100), 4 * 2 * 100 * 128 * 2);
    }
}
