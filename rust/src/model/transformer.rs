//! Tiny-GPT forward passes over pluggable KV caches.
//!
//! Pre-LN decoder-only transformer:
//! `x += Attn(LN1(x))`, `x += MLP(LN2(x))`, GELU MLP, learned positional
//! embeddings, untied LM head. Must match `python/compile/model.py` exactly
//! (golden parity tests in `tests/parity.rs`).
//!
//! Prefill runs dense causal attention with *exact* K/V (as a FlashAttention
//! prefill would) and then hands the K/V matrices to the cache, which may
//! compress them (GEAR) or prune them (H₂O). Decode steps attend through
//! the cache only — compression error therefore affects decoding exactly as
//! in the paper's system.
//!
//! ## Batched decode
//!
//! [`Model::decode_batch`] advances a whole batch of requests one token in
//! a single call, traversing the weights **layer-major** (layer `l` for
//! every request before layer `l+1`) so each block's matrices stay hot in
//! cache across the batch, with all intermediate buffers in a reusable
//! [`DecodeBufs`] — including the per-slot hidden-state pool, so a steady
//! decode step allocates nothing ([`Model::decode_batch_into`] also writes
//! logits into caller-pooled vectors). Per request it performs *exactly*
//! the same floating-point operations in the same order as
//! [`Model::decode_step`] — both funnel through the same `layer_forward` —
//! so batched decoding is bit-identical to the one-request-at-a-time path
//! (the engine's golden test pins this).
//!
//! [`Model::decode_layer_range`] exposes the same per-layer loop over a
//! contiguous layer range, for the executor's layer-sharded pipeline plane:
//! stage boundaries only partition the loop, every layer still funnels
//! through the shared `layer_forward`, so pipelined decode is bit-identical
//! too.
//!
//! Decode appends go through [`LayerKv::append_deferred`]: a streaming
//! buffer that reaches capacity is sealed for the engine's commit-point
//! flush (run in parallel on the executor pool) instead of compressing
//! inline in the layer loop. Standalone decode loops are unaffected — a
//! sealed buffer self-heals at the next append.

use crate::kvcache::{AttendScratch, LayerKv, RequestCache};
use crate::tensor::ops::{self, dot, gelu, layernorm, matmul, softmax_inplace};
use crate::tensor::Tensor;

use super::config::ModelConfig;
use super::weights::ModelWeights;

/// Weight matrices pre-transposed for GEMV dot-product form (decode path).
struct BlockT {
    wq_t: Tensor, // d × d, row j = column j of wq
    wk_t: Tensor,
    wv_t: Tensor,
    wo_t: Tensor,
    w1_t: Tensor, // 4d × d
    w2_t: Tensor, // d × 4d
}

/// Inference model: weights + derived transposed copies.
pub struct Model {
    pub weights: ModelWeights,
    blocks_t: Vec<BlockT>,
    head_t: Tensor, // vocab × d
}

/// Output of a prefill pass.
pub struct PrefillOutput {
    /// Logits at the last prompt position (vocab).
    pub last_logits: Vec<f32>,
}

impl Model {
    pub fn new(weights: ModelWeights) -> Model {
        let blocks_t = weights
            .blocks
            .iter()
            .map(|b| BlockT {
                wq_t: b.wq.t(),
                wk_t: b.wk.t(),
                wv_t: b.wv.t(),
                wo_t: b.wo.t(),
                w1_t: b.w1.t(),
                w2_t: b.w2.t(),
            })
            .collect();
        let head_t = weights.head.t();
        Model { weights, blocks_t, head_t }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    /// Embed `tokens` starting at position `pos0`.
    fn embed(&self, tokens: &[u32], pos0: usize) -> Tensor {
        let c = self.config();
        let d = c.d_model;
        let mut x = Tensor::zeros(&[tokens.len(), d]);
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            assert!(t < c.vocab, "token id {t} out of vocab");
            let p = pos0 + i;
            assert!(p < c.max_seq, "position {p} exceeds max_seq {}", c.max_seq);
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = self.weights.emb.row(t)[j] + self.weights.pos.row(p)[j];
            }
        }
        x
    }

    /// Embed a single `token` at `pos` into `out` (`d_model` long) without
    /// allocating — the decode path's per-slot hidden states are pooled in
    /// [`DecodeBufs`]. Value-identical to `embed(&[token], pos)`. Exposed
    /// crate-wide for the executor's pipeline plane, whose first stage
    /// embeds on a pool worker.
    pub(crate) fn embed_token_into(&self, token: u32, pos: usize, out: &mut [f32]) {
        let c = self.config();
        let t = token as usize;
        assert!(t < c.vocab, "token id {t} out of vocab");
        assert!(pos < c.max_seq, "position {pos} exceeds max_seq {}", c.max_seq);
        let emb = self.weights.emb.row(t);
        let pe = self.weights.pos.row(pos);
        for (o, (e, p)) in out.iter_mut().zip(emb.iter().zip(pe)) {
            *o = e + p;
        }
    }

    /// Prefill the prompt, populating `cache`, and return last-position
    /// logits. `cache` must be empty.
    ///
    /// Implemented as a single maximal chunk through the chunked-prefill
    /// plane ([`Self::prefill_chunk_batch`] + [`Self::commit_prefill`]), so
    /// the whole-prompt and chunked paths share one attention loop and stay
    /// bit-identical by construction.
    pub fn prefill(&self, tokens: &[u32], cache: &mut RequestCache) -> PrefillOutput {
        assert!(!tokens.is_empty(), "empty prompt");
        assert!(cache.is_empty(), "prefill into non-empty cache");
        let mut state = PrefillState::new(self.config(), tokens.len());
        let mut bufs = DecodeBufs::new(self.config());
        self.prefill_chunk_batch(&mut [PrefillSlot { tokens, state: &mut state }], &mut bufs);
        let last_logits = self.commit_prefill(state, cache);
        PrefillOutput { last_logits }
    }

    /// Advance every slot's in-flight prefill by its chunk of tokens, in a
    /// single layer-major pass (layer `l` runs for every slot before layer
    /// `l+1`, mirroring [`Self::decode_batch_with`]).
    ///
    /// Each chunk attends densely and causally against the *exact* f32 K/V
    /// rows accumulated in its [`PrefillState`] (prior chunks) plus its own
    /// rows — op-for-op the same computation a whole-prompt prefill performs
    /// on those rows, so the resulting hidden states, K/V matrices, and
    /// final logits are bit-identical regardless of how the prompt is
    /// chunked. (The only order-sensitive difference is the H₂O attention-
    /// mass accumulator, whose float additions regroup across chunks; see
    /// `PrefillState::mass`.)
    ///
    /// `bufs.attend.scores` is reused as the per-row score scratch; no other
    /// state in `bufs` is touched.
    pub fn prefill_chunk_batch(&self, slots: &mut [PrefillSlot<'_>], bufs: &mut DecodeBufs) {
        let c = self.config();
        let (d, nh) = (c.d_model, c.n_heads);
        let dh = c.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();

        // Per-slot chunk hidden states, embedded at each slot's resume
        // position.
        let mut xs: Vec<Tensor> = slots
            .iter()
            .map(|s| {
                assert!(!s.tokens.is_empty(), "empty prefill chunk");
                assert!(
                    s.state.done + s.tokens.len() <= s.state.total,
                    "chunk overruns prompt: {} + {} > {}",
                    s.state.done,
                    s.tokens.len(),
                    s.state.total
                );
                self.embed(s.tokens, s.state.done)
            })
            .collect();

        for (l, blk) in self.weights.blocks.iter().enumerate() {
            for (x, slot) in xs.iter_mut().zip(slots.iter_mut()) {
                let m = slot.tokens.len();
                let done = slot.state.done;
                let mut norm = Tensor::zeros(&[m, d]);
                for i in 0..m {
                    layernorm(x.row(i), &blk.ln1_g, &blk.ln1_b, 1e-5, norm.row_mut(i));
                }
                let q = matmul(&norm, &blk.wq);
                let k = matmul(&norm, &blk.wk);
                let v = matmul(&norm, &blk.wv);

                // Stash the chunk's exact K/V rows; attention then reads
                // rows 0..done+m contiguously out of the state.
                let st = &mut *slot.state;
                st.k[l].extend_from_slice(k.data());
                st.v[l].extend_from_slice(v.data());
                st.mass[l].resize(done + m, 0.0);
                let k_all = &st.k[l];
                let v_all = &st.v[l];
                let mass = &mut st.mass[l];

                // Dense causal attention per head (+ H₂O mass accumulation).
                let mut ctx = Tensor::zeros(&[m, d]);
                let row_scores = &mut bufs.attend.scores;
                row_scores.clear();
                row_scores.resize(done + m, 0.0);
                for h in 0..nh {
                    let hs = h * dh;
                    for i in 0..m {
                        let g = done + i;
                        let qrow = &q.row(i)[hs..hs + dh];
                        for t in 0..=g {
                            row_scores[t] =
                                scale * dot(qrow, &k_all[t * d + hs..t * d + hs + dh]);
                        }
                        softmax_inplace(&mut row_scores[..=g]);
                        let crow = &mut ctx.row_mut(i)[hs..hs + dh];
                        for t in 0..=g {
                            let p = row_scores[t];
                            mass[t] += p;
                            ops::axpy(p, &v_all[t * d + hs..t * d + hs + dh], crow);
                        }
                    }
                }
                let proj = matmul(&ctx, &blk.wo);
                for (xi, pi) in x.data_mut().iter_mut().zip(proj.data()) {
                    *xi += pi;
                }

                // MLP
                for i in 0..m {
                    layernorm(x.row(i), &blk.ln2_g, &blk.ln2_b, 1e-5, norm.row_mut(i));
                }
                let mut h1 = matmul(&norm, &blk.w1);
                for i in 0..m {
                    for (j, hv) in h1.row_mut(i).iter_mut().enumerate() {
                        *hv = gelu(*hv + blk.b1[j]);
                    }
                }
                let h2 = matmul(&h1, &blk.w2);
                for i in 0..m {
                    for j in 0..d {
                        x.row_mut(i)[j] += h2.row(i)[j] + blk.b2[j];
                    }
                }
            }
        }

        // Advance each slot; the final chunk yields last-position logits.
        for (x, slot) in xs.iter().zip(slots.iter_mut()) {
            slot.state.done += slot.tokens.len();
            if slot.state.done == slot.state.total {
                let mut last = vec![0.0f32; d];
                layernorm(
                    x.row(slot.tokens.len() - 1),
                    &self.weights.lnf_g,
                    &self.weights.lnf_b,
                    1e-5,
                    &mut last,
                );
                slot.state.last_logits = Some(self.lm_head(&last));
            }
        }
    }

    /// Commit a *complete* prefill: hand each layer's exact K/V (and H₂O
    /// attention mass) to the cache in one shot — the same
    /// `ingest_prefill` call a whole-prompt prefill makes, so compression
    /// layout and bytes are identical however the prompt was chunked.
    /// Returns the last-position logits.
    pub fn commit_prefill(&self, state: PrefillState, cache: &mut RequestCache) -> Vec<f32> {
        assert!(cache.is_empty(), "prefill into non-empty cache");
        assert!(
            state.is_complete(),
            "commit of incomplete prefill ({}/{} tokens)",
            state.done,
            state.total
        );
        let PrefillState { k, v, mass, total, d, last_logits, .. } = state;
        for (l, ((kl, vl), ml)) in k.into_iter().zip(v).zip(mass).enumerate() {
            let kt = Tensor::new(&[total, d], kl);
            let vt = Tensor::new(&[total, d], vl);
            cache.layers[l].ingest_prefill(kt, vt, Some(&ml));
        }
        last_logits.expect("complete prefill must have produced logits")
    }

    /// One decode step: embed `token` at `pos`, attend through the cache,
    /// return logits. Allocates a fresh [`DecodeBufs`]; loops that decode
    /// many steps should hold one and call [`Self::decode_step_with`].
    pub fn decode_step(&self, token: u32, pos: usize, cache: &mut RequestCache) -> Vec<f32> {
        let mut bufs = DecodeBufs::new(self.config());
        self.decode_step_with(token, pos, cache, &mut bufs)
    }

    /// One decode step using caller-owned scratch buffers.
    pub fn decode_step_with(
        &self,
        token: u32,
        pos: usize,
        cache: &mut RequestCache,
        bufs: &mut DecodeBufs,
    ) -> Vec<f32> {
        let mut x = vec![0.0f32; self.config().d_model];
        self.embed_token_into(token, pos, &mut x);
        for l in 0..self.weights.blocks.len() {
            self.layer_forward(l, &mut x, cache.layers[l].as_mut(), bufs);
        }
        self.finish_logits(&x, bufs)
    }

    /// Advance every slot one token in a single batched step.
    ///
    /// The traversal is layer-major: layer `l` runs for every request
    /// before layer `l+1`, so each block's (transposed) weight matrices are
    /// streamed once per step for the whole batch instead of once per
    /// request. Logits are returned in slot order. Allocates scratch; the
    /// executor uses [`Self::decode_batch_into`] with per-worker pinned
    /// buffers and pooled outputs.
    pub fn decode_batch(&self, steps: &mut [DecodeSlot]) -> Vec<Vec<f32>> {
        let mut bufs = DecodeBufs::new(self.config());
        self.decode_batch_with(steps, &mut bufs)
    }

    /// Batched decode step with caller-owned scratch. Per request this is
    /// op-for-op identical to [`Self::decode_step_with`]. Allocates the
    /// logits vectors; the executor pool uses [`Self::decode_batch_into`]
    /// with pooled outputs.
    pub fn decode_batch_with(
        &self,
        steps: &mut [DecodeSlot],
        bufs: &mut DecodeBufs,
    ) -> Vec<Vec<f32>> {
        let mut out: Vec<Vec<f32>> = (0..steps.len()).map(|_| Vec::new()).collect();
        self.decode_batch_into(steps, bufs, &mut out);
        out
    }

    /// Batched decode step writing logits into caller-pooled vectors: each
    /// `out[i]` is resized to the vocab and overwritten in place, so a
    /// caller that reuses `out` (and `bufs`, whose per-slot hidden-state
    /// pool this fills) across sweeps performs no per-sweep allocation
    /// beyond first-use growth. `out` must have exactly one slot per step.
    pub fn decode_batch_into(
        &self,
        steps: &mut [DecodeSlot],
        bufs: &mut DecodeBufs,
        out: &mut [Vec<f32>],
    ) {
        let b = steps.len();
        assert_eq!(out.len(), b, "one logits slot per decode slot");
        let d = self.config().d_model;
        if bufs.hidden.len() < b {
            bufs.hidden.resize_with(b, Vec::new);
        }
        // Take the pool out of `bufs` so the layer loop can borrow `bufs`
        // mutably alongside the per-slot hidden states.
        let mut hidden = std::mem::take(&mut bufs.hidden);
        for (x, s) in hidden.iter_mut().zip(steps.iter()) {
            x.resize(d, 0.0);
            self.embed_token_into(s.token, s.pos, x);
        }
        for l in 0..self.weights.blocks.len() {
            for (x, slot) in hidden.iter_mut().zip(steps.iter_mut()) {
                self.layer_forward(l, x, slot.cache.layers[l].as_mut(), bufs);
            }
        }
        for (x, o) in hidden.iter().zip(out.iter_mut()) {
            self.finish_logits_into(x, bufs, o);
        }
        bufs.hidden = hidden;
    }

    /// Advance one request's hidden state `x` through the contiguous layer
    /// range starting at global layer `first_layer`, one cache layer per
    /// model layer. This is the pipeline plane's per-stage entry point: a
    /// full pass over `first_layer = 0` with all the cache's layers is
    /// op-for-op the layer loop inside [`Self::decode_step_with`], so
    /// splitting a decode step across stages cannot change a single float —
    /// each layer still runs through the one shared `layer_forward`.
    ///
    /// `layers` must hold exactly the cache layers for model layers
    /// `first_layer .. first_layer + layers.len()`.
    pub fn decode_layer_range(
        &self,
        first_layer: usize,
        layers: &mut [Box<dyn LayerKv>],
        x: &mut [f32],
        bufs: &mut DecodeBufs,
    ) {
        debug_assert!(first_layer + layers.len() <= self.weights.blocks.len());
        for (off, layer) in layers.iter_mut().enumerate() {
            self.layer_forward(first_layer + off, x, layer.as_mut(), bufs);
        }
    }

    /// One transformer block over a single request's hidden state `x`
    /// (d-long), reading/writing its KV cache layer. Shared by the
    /// sequential and batched decode paths — bit-identity between them
    /// rests on this being the only implementation.
    fn layer_forward(
        &self,
        l: usize,
        x: &mut [f32],
        layer: &mut dyn LayerKv,
        bufs: &mut DecodeBufs,
    ) {
        let c = self.config();
        let (d, nh) = (c.d_model, c.n_heads);
        let blk = &self.weights.blocks[l];
        let bt = &self.blocks_t[l];

        layernorm(x, &blk.ln1_g, &blk.ln1_b, 1e-5, &mut bufs.norm);
        // GEMV via transposed weights (unit-stride dot products).
        let (qs, rest) = bufs.qkv.split_at_mut(d);
        let (ks, vs) = rest.split_at_mut(d);
        gemv_t(&bt.wq_t, &bufs.norm, qs);
        gemv_t(&bt.wk_t, &bufs.norm, ks);
        gemv_t(&bt.wv_t, &bufs.norm, vs);

        // Deferred-flush append: a buffer this fills is sealed for the
        // engine's commit-point flush instead of compressing inline here.
        layer.append_deferred(ks, vs);
        layer.attend_scratch(qs, nh, &mut bufs.attend, &mut bufs.ctx);

        // x += ctx @ Wo
        gemv_t(&bt.wo_t, &bufs.ctx, &mut bufs.proj);
        for (xi, pi) in x.iter_mut().zip(&bufs.proj) {
            *xi += pi;
        }

        layernorm(x, &blk.ln2_g, &blk.ln2_b, 1e-5, &mut bufs.norm);
        gemv_t(&bt.w1_t, &bufs.norm, &mut bufs.h1);
        for (j, hv) in bufs.h1.iter_mut().enumerate() {
            *hv = gelu(*hv + blk.b1[j]);
        }
        gemv_t(&bt.w2_t, &bufs.h1, &mut bufs.h2);
        for j in 0..d {
            x[j] += bufs.h2[j] + blk.b2[j];
        }
    }

    /// Final LayerNorm + LM head over a finished hidden state.
    fn finish_logits(&self, x: &[f32], bufs: &mut DecodeBufs) -> Vec<f32> {
        let mut out = Vec::new();
        self.finish_logits_into(x, bufs, &mut out);
        out
    }

    /// [`Self::finish_logits`] into a caller-pooled vector (resized to the
    /// vocab, fully overwritten). Exposed crate-wide for the executor's
    /// pipeline plane, whose last stage finishes logits on a pool worker.
    pub(crate) fn finish_logits_into(&self, x: &[f32], bufs: &mut DecodeBufs, out: &mut Vec<f32>) {
        layernorm(x, &self.weights.lnf_g, &self.weights.lnf_b, 1e-5, &mut bufs.norm);
        out.resize(self.config().vocab, 0.0);
        gemv_t(&self.head_t, &bufs.norm, out);
    }

    fn lm_head(&self, x: &[f32]) -> Vec<f32> {
        let c = self.config();
        let mut logits = vec![0.0f32; c.vocab];
        gemv_t(&self.head_t, x, &mut logits);
        logits
    }
}

/// One request's slice of a batched decode step: the token sampled at the
/// previous step, the position it lands at, and the request's cache.
pub struct DecodeSlot<'a> {
    pub token: u32,
    pub pos: usize,
    pub cache: &'a mut RequestCache,
}

/// One request's slice of a batched prefill round: the next chunk of prompt
/// tokens and the request's in-flight prefill state.
pub struct PrefillSlot<'a> {
    pub tokens: &'a [u32],
    pub state: &'a mut PrefillState,
}

/// In-flight chunked prefill of one request: the *exact* f32 K/V rows of
/// every prompt token processed so far, per layer, plus the H₂O
/// attention-mass accumulators.
///
/// Keeping the rows exact (not FP16-rounded, not compressed) is what makes
/// chunked prefill bit-identical to whole-prompt prefill: later chunks
/// attend against precisely the values a single dense pass would have used,
/// and [`Model::commit_prefill`] compresses the concatenated matrices in
/// the same one-shot `ingest_prefill` call. The f32 copies are a
/// host-simulation artifact of that exactness; for byte-budget purposes the
/// in-flight KV is accounted at the FP16 rate a serving system would hold
/// it at ([`Self::transient_fp16_bytes`]).
pub struct PrefillState {
    /// Per-layer exact K rows, row-major `done × d`.
    k: Vec<Vec<f32>>,
    /// Per-layer exact V rows, row-major `done × d`.
    v: Vec<Vec<f32>>,
    /// Per-layer accumulated attention mass per prompt token (H₂O's prefill
    /// oracle). Float additions regroup across chunk boundaries, so this is
    /// the one prefill output that is equal only up to rounding between
    /// chunkings.
    mass: Vec<Vec<f32>>,
    /// Prompt tokens prefilled so far.
    done: usize,
    /// Total prompt length.
    total: usize,
    d: usize,
    /// Set by the chunk that completes the prompt.
    last_logits: Option<Vec<f32>>,
}

impl PrefillState {
    pub fn new(c: &ModelConfig, prompt_len: usize) -> PrefillState {
        assert!(prompt_len > 0, "empty prompt");
        let layer = || Vec::with_capacity(prompt_len * c.d_model);
        PrefillState {
            k: (0..c.n_layers).map(|_| layer()).collect(),
            v: (0..c.n_layers).map(|_| layer()).collect(),
            mass: (0..c.n_layers).map(|_| Vec::new()).collect(),
            done: 0,
            total: prompt_len,
            d: c.d_model,
            last_logits: None,
        }
    }

    /// Prompt tokens prefilled so far.
    pub fn done(&self) -> usize {
        self.done
    }

    /// Total prompt length.
    pub fn total(&self) -> usize {
        self.total
    }

    pub fn is_complete(&self) -> bool {
        self.done == self.total
    }

    /// FP16-accounted bytes of the in-flight K/V once `tokens` prompt
    /// tokens are prefilled (K + V rows across all layers). The scheduler
    /// reserves this against the byte budget while the prefill is in
    /// flight; it equals `ModelConfig::fp16_kv_bytes(tokens)`.
    pub fn transient_fp16_bytes(&self, tokens: usize) -> usize {
        self.k.len() * 2 * tokens * self.d * 2
    }
}

/// Reusable scratch for decode steps: every intermediate the per-layer
/// forward needs, the cache-attention scratch, and the per-slot
/// hidden-state pool for batched steps. One per executor pool worker,
/// pinned for the worker's lifetime; contents are fully overwritten before
/// use, so sharing one instance across requests and sweeps cannot change
/// results.
#[derive(Debug, Clone)]
pub struct DecodeBufs {
    norm: Vec<f32>,
    qkv: Vec<f32>,
    ctx: Vec<f32>,
    proj: Vec<f32>,
    h1: Vec<f32>,
    h2: Vec<f32>,
    attend: AttendScratch,
    /// Per-slot hidden states for [`Model::decode_batch_into`]; grows to
    /// the largest batch seen and is reused across sweeps.
    hidden: Vec<Vec<f32>>,
}

impl DecodeBufs {
    pub fn new(c: &ModelConfig) -> DecodeBufs {
        let d = c.d_model;
        DecodeBufs {
            norm: vec![0.0; d],
            qkv: vec![0.0; 3 * d],
            ctx: vec![0.0; d],
            proj: vec![0.0; d],
            h1: vec![0.0; c.mlp_dim()],
            h2: vec![0.0; d],
            attend: AttendScratch::default(),
            hidden: Vec::new(),
        }
    }
}

/// out[i] = dot(wt.row(i), x) — GEMV with a pre-transposed weight matrix.
#[inline]
fn gemv_t(wt: &Tensor, x: &[f32], out: &mut [f32]) {
    let (rows, cols) = (wt.rows(), wt.cols());
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(out.len(), rows);
    let data = wt.data();
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(&data[i * cols..(i + 1) * cols], x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheSpec;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    fn tiny_model() -> Model {
        let cfg = ModelConfig { vocab: 13, d_model: 32, n_layers: 2, n_heads: 4, max_seq: 64 };
        Model::new(ModelWeights::random(cfg, 42))
    }

    fn new_cache(model: &Model, spec: &CacheSpec) -> RequestCache {
        let c = model.config();
        RequestCache::new(spec, c.n_layers, c.d_model, c.n_heads)
    }

    #[test]
    fn prefill_then_decode_runs() {
        let m = tiny_model();
        let mut cache = new_cache(&m, &CacheSpec::Fp16);
        let out = m.prefill(&[1, 3, 5, 7], &mut cache);
        assert_eq!(out.last_logits.len(), 13);
        assert!(out.last_logits.iter().all(|x| x.is_finite()));
        assert_eq!(cache.len(), 4);
        let logits = m.decode_step(2, 4, &mut cache);
        assert_eq!(cache.len(), 5);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    /// Decoding token t+1 with an FP16 cache must match what a fresh prefill
    /// of the extended prompt computes — the incremental path is consistent
    /// with the batch path (up to fp16 cache rounding).
    #[test]
    fn incremental_matches_prefill() {
        let m = tiny_model();
        let prompt = [1u32, 3, 5, 7, 9, 2];

        let mut c1 = new_cache(&m, &CacheSpec::Fp16);
        let full = m.prefill(&prompt, &mut c1);

        let mut c2 = new_cache(&m, &CacheSpec::Fp16);
        let _ = m.prefill(&prompt[..5], &mut c2);
        let step = m.decode_step(prompt[5], 5, &mut c2);

        for (a, b) in full.last_logits.iter().zip(&step) {
            assert!((a - b).abs() < 0.02, "prefill {a} vs incremental {b}");
        }
    }

    #[test]
    fn gear_cache_decoding_close_to_fp16_at_8bit() {
        let m = tiny_model();
        let prompt = [1u32, 3, 5, 7, 9, 2, 4, 6];
        let spec8 = CacheSpec::Compressed {
            method: crate::gear::Method::Gear {
                bits: 8,
                backbone: crate::gear::compose::Backbone::Kivi(8),
                s: 0.02,
                r: 4,
            },
            buffer: 4,
            prefill_rank: 4,
            decode_rank: 2,
        };
        let mut cf = new_cache(&m, &CacheSpec::Fp16);
        let mut cg = new_cache(&m, &spec8);
        m.prefill(&prompt, &mut cf);
        m.prefill(&prompt, &mut cg);
        let lf = m.decode_step(3, 8, &mut cf);
        let lg = m.decode_step(3, 8, &mut cg);
        let dist = crate::tensor::ops::fro_dist(&lf, &lg);
        let norm = crate::tensor::ops::fro_norm(&lf);
        assert!(dist / norm < 0.05, "8-bit logit deviation {}", dist / norm);
    }

    #[test]
    fn h2o_cache_end_to_end() {
        let m = tiny_model();
        let mut c = new_cache(&m, &CacheSpec::H2o { keep: 0.5, recent: 2 });
        m.prefill(&[1, 3, 5, 7, 9, 2, 4, 6], &mut c);
        assert!(c.len() <= 4); // pruned to 50%
        let logits = m.decode_step(3, 8, &mut c);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    /// The batched decode plane must be bit-identical to step-at-a-time
    /// decoding: same tokens, same caches, exactly equal logits.
    #[test]
    fn decode_batch_bit_identical_to_decode_step() {
        let m = tiny_model();
        let specs = [
            CacheSpec::Fp16,
            CacheSpec::gear(4),
            CacheSpec::H2o { keep: 0.6, recent: 2 },
        ];
        let prompts: [&[u32]; 3] = [&[1, 3, 5, 7], &[2, 4, 6], &[9, 8, 7, 6, 5]];

        // Reference: sequential decode_step per request.
        let mut seq_caches: Vec<RequestCache> =
            specs.iter().map(|s| new_cache(&m, s)).collect();
        let mut seq_logits = Vec::new();
        for step in 0..4 {
            let mut per_req = Vec::new();
            for (i, cache) in seq_caches.iter_mut().enumerate() {
                if step == 0 {
                    m.prefill(prompts[i], cache);
                }
                let tok = (i as u32 + step as u32) % 13;
                per_req.push(m.decode_step(tok, prompts[i].len() + step, cache));
            }
            seq_logits.push(per_req);
        }

        // Batched: same requests through decode_batch.
        let mut bat_caches: Vec<RequestCache> =
            specs.iter().map(|s| new_cache(&m, s)).collect();
        for (i, cache) in bat_caches.iter_mut().enumerate() {
            let _ = m.prefill(prompts[i], cache);
        }
        for step in 0..4 {
            let mut slots: Vec<DecodeSlot> = bat_caches
                .iter_mut()
                .enumerate()
                .map(|(i, cache)| DecodeSlot {
                    token: (i as u32 + step as u32) % 13,
                    pos: prompts[i].len() + step,
                    cache,
                })
                .collect();
            let batched = m.decode_batch(&mut slots);
            for (i, lg) in batched.iter().enumerate() {
                assert_eq!(lg, &seq_logits[step][i], "req {i} step {step} diverged");
            }
        }
    }

    /// Chunked prefill must be bit-identical to whole-prompt prefill —
    /// same final logits, same committed cache bytes, and an exactly equal
    /// first decode step — for every chunking of the prompt.
    #[test]
    fn chunked_prefill_bit_identical_to_whole() {
        let m = tiny_model();
        let prompt: Vec<u32> = (0..23).map(|i| (i % 11) + 1).collect();
        for spec in [CacheSpec::Fp16, CacheSpec::gear(4), CacheSpec::parse("kivi-2").unwrap()] {
            let run = |chunk: usize| {
                let mut cache = new_cache(&m, &spec);
                let logits = if chunk >= prompt.len() {
                    // Whole-prompt entry point (itself a single chunk).
                    m.prefill(&prompt, &mut cache).last_logits
                } else {
                    let mut state = PrefillState::new(m.config(), prompt.len());
                    let mut bufs = DecodeBufs::new(m.config());
                    let mut done = 0;
                    while done < prompt.len() {
                        let end = (done + chunk).min(prompt.len());
                        let mut slots =
                            [PrefillSlot { tokens: &prompt[done..end], state: &mut state }];
                        m.prefill_chunk_batch(&mut slots, &mut bufs);
                        done = end;
                    }
                    m.commit_prefill(state, &mut cache)
                };
                let dec = m.decode_step(5, prompt.len(), &mut cache);
                (logits, dec, cache.nbytes())
            };
            let whole = run(usize::MAX);
            for chunk in [1usize, 4, 7, 16] {
                assert_eq!(run(chunk), whole, "chunk {} spec {}", chunk, spec.label());
            }
        }
    }

    /// A multi-slot prefill round must leave each slot exactly as a
    /// single-slot round would (slots are independent).
    #[test]
    fn batched_prefill_slots_independent() {
        let m = tiny_model();
        let prompts: [&[u32]; 3] = [&[1, 3, 5, 7, 9], &[2, 4, 6], &[9, 8, 7, 6, 5, 4, 3]];
        let solo: Vec<(Vec<f32>, usize)> = prompts
            .iter()
            .map(|p| {
                let mut cache = new_cache(&m, &CacheSpec::gear(4));
                let out = m.prefill(p, &mut cache);
                (out.last_logits, cache.nbytes())
            })
            .collect();

        // Same prompts, prefilled together two chunked rounds at a time.
        let mut states: Vec<PrefillState> =
            prompts.iter().map(|p| PrefillState::new(m.config(), p.len())).collect();
        let mut bufs = DecodeBufs::new(m.config());
        let chunk = 2;
        let mut done = 0;
        while states.iter().any(|s| !s.is_complete()) {
            let mut slots: Vec<PrefillSlot> = Vec::new();
            for (p, s) in prompts.iter().zip(states.iter_mut()) {
                if done < p.len() {
                    let end = (done + chunk).min(p.len());
                    slots.push(PrefillSlot { tokens: &p[done..end], state: s });
                }
            }
            m.prefill_chunk_batch(&mut slots, &mut bufs);
            done += chunk;
        }
        for ((state, p), (logits, nbytes)) in states.into_iter().zip(prompts).zip(solo) {
            let mut cache = new_cache(&m, &CacheSpec::gear(4));
            assert_eq!(state.done(), p.len());
            assert_eq!(m.commit_prefill(state, &mut cache), logits);
            assert_eq!(cache.nbytes(), nbytes);
        }
    }

    /// H₂O's attention-mass accumulator regroups float additions across
    /// chunk boundaries, so chunked H₂O prefill is equivalent but not
    /// bit-pinned; pruning behavior must still match.
    #[test]
    fn chunked_prefill_h2o_prunes_identically() {
        let m = tiny_model();
        let spec = CacheSpec::H2o { keep: 0.5, recent: 2 };
        let prompt: Vec<u32> = (0..20).map(|i| (i % 12) + 1).collect();
        let mut whole = new_cache(&m, &spec);
        m.prefill(&prompt, &mut whole);

        let mut state = PrefillState::new(m.config(), prompt.len());
        let mut bufs = DecodeBufs::new(m.config());
        for start in (0..prompt.len()).step_by(6) {
            let end = (start + 6).min(prompt.len());
            let mut slots = [PrefillSlot { tokens: &prompt[start..end], state: &mut state }];
            m.prefill_chunk_batch(&mut slots, &mut bufs);
        }
        let mut chunked = new_cache(&m, &spec);
        let logits = m.commit_prefill(state, &mut chunked);
        assert_eq!(chunked.len(), whole.len(), "same pruned token count");
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    #[should_panic(expected = "non-empty cache")]
    fn prefill_twice_panics() {
        let m = tiny_model();
        let mut c = new_cache(&m, &CacheSpec::Fp16);
        m.prefill(&[1, 2], &mut c);
        m.prefill(&[1, 2], &mut c);
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn bad_token_panics() {
        let m = tiny_model();
        let mut c = new_cache(&m, &CacheSpec::Fp16);
        m.prefill(&[99], &mut c);
    }
}
