//! Token sampling from logits.

use crate::util::rng::Rng;

/// Sampling configuration for a generation request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampler {
    /// Argmax decoding (the paper's evaluation setting).
    Greedy,
    /// Softmax sampling at the given temperature (> 0).
    Temperature(f32),
    /// Top-k truncation then temperature sampling.
    TopK { k: usize, temperature: f32 },
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match *self {
            Sampler::Greedy => argmax(logits),
            Sampler::Temperature(t) => {
                assert!(t > 0.0);
                sample_softmax(logits, t, None, rng)
            }
            Sampler::TopK { k, temperature } => {
                assert!(temperature > 0.0 && k > 0);
                sample_softmax(logits, temperature, Some(k), rng)
            }
        }
    }
}

/// Index of the maximum logit under IEEE total order (ties broken toward
/// the lower id, so greedy decoding is fully deterministic — even if a
/// buggy forward pass produces NaNs, every process picks the same token
/// rather than whichever index a `>` comparison happened to skip).
pub fn argmax(logits: &[f32]) -> u32 {
    debug_assert!(!logits.is_empty(), "argmax over empty logits");
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v.total_cmp(&logits[best]).is_gt() {
            best = i;
        }
    }
    best as u32
}

fn sample_softmax(logits: &[f32], temperature: f32, top_k: Option<usize>, rng: &mut Rng) -> u32 {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if let Some(k) = top_k {
        idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        idx.truncate(k.min(logits.len()));
    }
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> =
        idx.iter().map(|&i| (((logits[i] - max) / temperature) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.next_f64() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        u -= w;
        if u <= 0.0 {
            return i as u32;
        }
    }
    *idx.last().unwrap() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0, 5.0, 1.0]), 0); // tie -> lower id
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[Sampler::Temperature(1.0).sample(&logits, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 4.0, 0.0];
        for _ in 0..100 {
            assert_eq!(Sampler::Temperature(0.05).sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(3);
        let logits = [5.0f32, 4.9, -10.0, -10.0];
        for _ in 0..100 {
            let s = Sampler::TopK { k: 2, temperature: 1.0 }.sample(&logits, &mut rng);
            assert!(s <= 1, "sampled {s} outside top-2");
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "empty logits")]
    fn argmax_empty_is_a_bug() {
        argmax(&[]);
    }

    /// `top_k > vocab` degrades to plain temperature sampling over the full
    /// support rather than panicking or truncating wrongly.
    #[test]
    fn top_k_larger_than_vocab_covers_support() {
        let mut rng = Rng::new(4);
        let logits = [1.0f32, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            let s = Sampler::TopK { k: 10, temperature: 1.0 }.sample(&logits, &mut rng);
            seen[s as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "k > vocab must cover every token");
    }

    /// NaN logits get a fixed position in the IEEE total order (positive
    /// NaN above every number), so even a poisoned forward pass yields the
    /// same deterministic pick everywhere — never an index that depends on
    /// how `>` comparisons short-circuited.
    #[test]
    fn argmax_nan_deterministic() {
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 1);
        assert_eq!(argmax(&[f32::NAN, f32::NAN, 0.0]), 0); // tie -> lower id
        assert_eq!(argmax(&[-f32::NAN, 3.0, 1.0]), 1); // -NaN below numbers
    }
}
