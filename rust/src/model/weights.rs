//! Model checkpoint loading.
//!
//! Format (written by `python/compile/train.py`), little-endian:
//!
//! ```text
//! magic   b"GSRV"
//! version u32 (= 1)
//! count   u32
//! repeat count times:
//!   name_len u32, name bytes (utf-8)
//!   ndim     u32, dims u32 × ndim
//!   data     f32 × prod(dims)
//! ```

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use crate::util::error::{bail, Context, Result};

use crate::tensor::Tensor;

use super::config::ModelConfig;

pub const MAGIC: &[u8; 4] = b"GSRV";
pub const VERSION: u32 = 1;

/// One transformer block's parameters.
#[derive(Debug, Clone)]
pub struct BlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Tensor, // d × d
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Tensor, // d × 4d
    pub b1: Vec<f32>,
    pub w2: Tensor, // 4d × d
    pub b2: Vec<f32>,
}

/// Full model parameters.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: ModelConfig,
    pub emb: Tensor, // vocab × d
    pub pos: Tensor, // max_seq × d
    pub blocks: Vec<BlockWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub head: Tensor, // d × vocab
}

/// Parse the raw tensor map from checkpoint bytes.
pub fn read_tensor_map(bytes: &[u8]) -> Result<HashMap<String, Tensor>> {
    let mut cur = std::io::Cursor::new(bytes);
    let mut magic = [0u8; 4];
    cur.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("bad magic {magic:?}, expected GSRV");
    }
    let version = read_u32(&mut cur)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let count = read_u32(&mut cur)? as usize;
    let mut map = HashMap::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut cur)? as usize;
        let mut name = vec![0u8; name_len];
        cur.read_exact(&mut name).context("reading tensor name")?;
        let name = String::from_utf8(name).context("tensor name utf-8")?;
        let ndim = read_u32(&mut cur)? as usize;
        if ndim > 4 {
            bail!("tensor {name}: ndim {ndim} > 4");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut cur)? as usize);
        }
        let n: usize = dims.iter().product();
        let mut data = vec![0.0f32; n];
        let mut buf = vec![0u8; n * 4];
        cur.read_exact(&mut buf).with_context(|| format!("reading {name} data"))?;
        for (i, chunk) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        map.insert(name, Tensor::new(&dims, data));
    }
    Ok(map)
}

fn read_u32(cur: &mut std::io::Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b).context("reading u32")?;
    Ok(u32::from_le_bytes(b))
}

/// Serialize a tensor map in checkpoint format (used by tests and tools;
/// the canonical writer is the Python trainer).
pub fn write_tensor_map(tensors: &[(String, Tensor)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
        for &d in t.shape() {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

impl ModelWeights {
    /// Load a checkpoint, inferring the configuration from tensor shapes.
    pub fn load(path: &Path) -> Result<ModelWeights> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<ModelWeights> {
        let mut map = read_tensor_map(bytes)?;
        fn take(map: &mut HashMap<String, Tensor>, name: &str) -> Result<Tensor> {
            map.remove(name).with_context(|| format!("checkpoint missing tensor {name}"))
        }
        let take_vec = |t: Tensor| -> Vec<f32> { t.into_data() };

        let emb = take(&mut map, "emb")?;
        let pos = take(&mut map, "pos")?;
        let head = take(&mut map, "head")?;
        let (vocab, d_model) = (emb.rows(), emb.cols());
        let max_seq = pos.rows();

        let n_layers = (0..)
            .take_while(|i| map.contains_key(&format!("blocks.{i}.attn.wq")))
            .count();
        if n_layers == 0 {
            bail!("checkpoint has no transformer blocks");
        }
        // Head count is recorded as a 1-element tensor.
        let n_heads = take(&mut map, "n_heads")?.data()[0] as usize;

        let mut blocks = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let mut t = |suffix: &str| -> Result<Tensor> {
                map.remove(&format!("blocks.{i}.{suffix}"))
                    .with_context(|| format!("checkpoint missing blocks.{i}.{suffix}"))
            };
            blocks.push(BlockWeights {
                ln1_g: take_vec(t("ln1.g")?),
                ln1_b: take_vec(t("ln1.b")?),
                wq: t("attn.wq")?,
                wk: t("attn.wk")?,
                wv: t("attn.wv")?,
                wo: t("attn.wo")?,
                ln2_g: take_vec(t("ln2.g")?),
                ln2_b: take_vec(t("ln2.b")?),
                w1: t("mlp.w1")?,
                b1: take_vec(t("mlp.b1")?),
                w2: t("mlp.w2")?,
                b2: take_vec(t("mlp.b2")?),
            });
        }

        let config = ModelConfig { vocab, d_model, n_layers, n_heads, max_seq };
        let w = ModelWeights {
            config,
            emb,
            pos,
            blocks,
            lnf_g: take_vec(take(&mut map, "ln_f.g")?),
            lnf_b: take_vec(take(&mut map, "ln_f.b")?),
            head,
        };
        w.validate()?;
        Ok(w)
    }

    /// Shape-check every tensor against the config.
    pub fn validate(&self) -> Result<()> {
        let c = &self.config;
        let d = c.d_model;
        if d % c.n_heads != 0 {
            bail!("d_model {d} not divisible by n_heads {}", c.n_heads);
        }
        let check = |name: &str, t: &Tensor, shape: &[usize]| -> Result<()> {
            if t.shape() != shape {
                bail!("{name}: shape {:?} != expected {shape:?}", t.shape());
            }
            Ok(())
        };
        check("emb", &self.emb, &[c.vocab, d])?;
        check("pos", &self.pos, &[c.max_seq, d])?;
        check("head", &self.head, &[d, c.vocab])?;
        for (i, b) in self.blocks.iter().enumerate() {
            check(&format!("blocks.{i}.wq"), &b.wq, &[d, d])?;
            check(&format!("blocks.{i}.wk"), &b.wk, &[d, d])?;
            check(&format!("blocks.{i}.wv"), &b.wv, &[d, d])?;
            check(&format!("blocks.{i}.wo"), &b.wo, &[d, d])?;
            check(&format!("blocks.{i}.w1"), &b.w1, &[d, c.mlp_dim()])?;
            check(&format!("blocks.{i}.w2"), &b.w2, &[c.mlp_dim(), d])?;
            for (n, v, want) in [
                ("ln1.g", &b.ln1_g, d),
                ("ln1.b", &b.ln1_b, d),
                ("ln2.g", &b.ln2_g, d),
                ("ln2.b", &b.ln2_b, d),
                ("mlp.b1", &b.b1, c.mlp_dim()),
                ("mlp.b2", &b.b2, d),
            ] {
                if v.len() != want {
                    bail!("blocks.{i}.{n}: len {} != {want}", v.len());
                }
            }
        }
        if self.lnf_g.len() != d || self.lnf_b.len() != d {
            bail!("ln_f size mismatch");
        }
        Ok(())
    }

    /// Random weights for tests / benches that don't need a trained model.
    pub fn random(config: ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = crate::util::rng::Rng::new(seed);
        let d = config.d_model;
        let s = 0.08f32;
        let block = |rng: &mut crate::util::rng::Rng| BlockWeights {
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            wq: Tensor::randn(&[d, d], rng, s),
            wk: Tensor::randn(&[d, d], rng, s),
            wv: Tensor::randn(&[d, d], rng, s),
            wo: Tensor::randn(&[d, d], rng, s),
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
            w1: Tensor::randn(&[d, config.mlp_dim()], rng, s),
            b1: vec![0.0; config.mlp_dim()],
            w2: Tensor::randn(&[config.mlp_dim(), d], rng, s),
            b2: vec![0.0; d],
        };
        ModelWeights {
            config,
            emb: Tensor::randn(&[config.vocab, d], &mut rng, s),
            pos: Tensor::randn(&[config.max_seq, d], &mut rng, s),
            blocks: (0..config.n_layers).map(|_| block(&mut rng)).collect(),
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            head: Tensor::randn(&[d, config.vocab], &mut rng, s),
        }
    }

    /// Serialize to checkpoint bytes (for round-trip tests).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut tensors: Vec<(String, Tensor)> = vec![
            ("emb".into(), self.emb.clone()),
            ("pos".into(), self.pos.clone()),
            ("head".into(), self.head.clone()),
            ("n_heads".into(), Tensor::new(&[1], vec![self.config.n_heads as f32])),
            ("ln_f.g".into(), Tensor::new(&[self.lnf_g.len()], self.lnf_g.clone())),
            ("ln_f.b".into(), Tensor::new(&[self.lnf_b.len()], self.lnf_b.clone())),
        ];
        for (i, b) in self.blocks.iter().enumerate() {
            let p = |s: &str| format!("blocks.{i}.{s}");
            tensors.push((p("ln1.g"), Tensor::new(&[b.ln1_g.len()], b.ln1_g.clone())));
            tensors.push((p("ln1.b"), Tensor::new(&[b.ln1_b.len()], b.ln1_b.clone())));
            tensors.push((p("attn.wq"), b.wq.clone()));
            tensors.push((p("attn.wk"), b.wk.clone()));
            tensors.push((p("attn.wv"), b.wv.clone()));
            tensors.push((p("attn.wo"), b.wo.clone()));
            tensors.push((p("ln2.g"), Tensor::new(&[b.ln2_g.len()], b.ln2_g.clone())));
            tensors.push((p("ln2.b"), Tensor::new(&[b.ln2_b.len()], b.ln2_b.clone())));
            tensors.push((p("mlp.w1"), b.w1.clone()));
            tensors.push((p("mlp.b1"), Tensor::new(&[b.b1.len()], b.b1.clone())));
            tensors.push((p("mlp.w2"), b.w2.clone()));
            tensors.push((p("mlp.b2"), Tensor::new(&[b.b2.len()], b.b2.clone())));
        }
        write_tensor_map(&tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_random_weights() {
        let cfg = ModelConfig { vocab: 11, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 8 };
        let w = ModelWeights::random(cfg, 7);
        let bytes = w.to_bytes();
        let w2 = ModelWeights::from_bytes(&bytes).unwrap();
        assert_eq!(w2.config, cfg);
        assert_eq!(w2.emb, w.emb);
        assert_eq!(w2.blocks[1].w2, w.blocks[1].w2);
        assert_eq!(w2.lnf_g, w.lnf_g);
    }

    #[test]
    fn rejects_bad_magic() {
        let err = ModelWeights::from_bytes(b"XXXX\x01\x00\x00\x00\x00\x00\x00\x00").unwrap_err();
        assert!(err.to_string().contains("magic"));
    }

    #[test]
    fn rejects_truncated() {
        let cfg = ModelConfig { vocab: 5, d_model: 8, n_layers: 1, n_heads: 2, max_seq: 4 };
        let bytes = ModelWeights::random(cfg, 1).to_bytes();
        assert!(ModelWeights::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn rejects_missing_tensor() {
        let cfg = ModelConfig { vocab: 5, d_model: 8, n_layers: 1, n_heads: 2, max_seq: 4 };
        let w = ModelWeights::random(cfg, 1);
        let mut map = read_tensor_map(&w.to_bytes()).unwrap();
        map.remove("ln_f.g");
        let tensors: Vec<(String, Tensor)> = map.into_iter().collect();
        let bytes = write_tensor_map(&tensors);
        let err = ModelWeights::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("ln_f.g"), "{err}");
    }

    #[test]
    fn validate_catches_shape_mismatch() {
        let cfg = ModelConfig { vocab: 5, d_model: 8, n_layers: 1, n_heads: 2, max_seq: 4 };
        let mut w = ModelWeights::random(cfg, 1);
        w.head = Tensor::zeros(&[8, 6]); // wrong vocab dim
        assert!(w.validate().is_err());
    }
}
