//! Tiny-GPT inference.
//!
//! The model architecture is defined twice — here (Rust, the request path)
//! and in `python/compile/model.py` (JAX, the build path that trains the
//! weights and lowers the AOT graphs). The two must stay in lockstep; the
//! golden-vector tests in `tests/` enforce logit parity.
//!
//! Prefill is chunkable: [`PrefillState`] carries a request's in-flight
//! exact K/V so the prompt can be processed in fixed-size chunks across
//! engine sweeps ([`Model::prefill_chunk_batch`]) with results bit-identical
//! to a whole-prompt pass.

pub mod config;
pub mod sampler;
pub mod transformer;
pub mod weights;

pub use config::{ModelConfig, Tokenizer};
pub use transformer::{Model, PrefillSlot, PrefillState};
pub use weights::ModelWeights;
