//! Tiny-GPT inference.
//!
//! The model architecture is defined twice — here (Rust, the request path)
//! and in `python/compile/model.py` (JAX, the build path that trains the
//! weights and lowers the AOT graphs). The two must stay in lockstep; the
//! golden-vector tests in `tests/` enforce logit parity.

pub mod config;
pub mod sampler;
pub mod transformer;
pub mod weights;

pub use config::{ModelConfig, Tokenizer};
pub use transformer::Model;
pub use weights::ModelWeights;
