//! Trace export: Chrome-trace/Perfetto JSON, the JSONL event journal
//! with its declared schema, and the schema-validating JSONL parser
//! used by `tests/trace_golden.rs` and the CI `trace` job.
//!
//! The crate builds offline with no serde, so both renderers emit JSON
//! by string formatting (the same approach as `bench_throughput`) and
//! the validator ships a tiny recursive-descent parser for the subset
//! of JSON the renderers produce (no string escapes — nothing we emit
//! needs them, and the parser rejects them loudly rather than guessing).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

use super::{Event, EventKind, FinishClass, SweepPhase, Tracer, Writer};
use crate::gear::KvKind;

/// Keys present on every JSONL event line, in order.
pub const BASE_FIELDS: &[&str] = &["t_ns", "dur_ns", "writer", "kind"];

/// Per-kind payload keys, in the order they follow the base keys on an
/// event line. This table *is* the declared schema: the emitter and
/// [`jsonl_schema_line`] both derive from it, and the unit tests render
/// one event of every kind through [`validate_jsonl`] so the two can
/// never drift apart silently.
pub const KIND_FIELDS: &[(&str, &[&str])] = &[
    ("enqueue", &["req_id"]),
    ("admit", &["serial", "req_id"]),
    ("reserve", &["serial", "bytes"]),
    ("prefill_chunk", &["serial", "rows"]),
    ("plane_chosen", &["batch", "pipelined"]),
    ("decode_step", &["n_seqs"]),
    ("first_token", &["serial"]),
    ("seal", &["serial", "layer", "rows"]),
    ("flush_submit", &["serial", "layer", "rows"]),
    ("flush_join", &["serial", "layer"]),
    ("preempt", &["serial", "oom"]),
    ("finish", &["serial", "reason", "tokens"]),
    (
        "quality",
        &[
            "serial",
            "layer",
            "rows",
            "prefill",
            "side",
            "bytes",
            "pred_bytes",
            "err_fro",
            "quant_resid_fro",
            "lowrank_fro",
            "outlier_fro",
        ],
    ),
    ("phase", &["phase"]),
    ("chunk", &["n_seqs"]),
    ("stage_span", &["stage", "busy"]),
    ("flush_run", &["layer"]),
];

fn writer_label(w: Writer) -> String {
    match w {
        Writer::Engine => "engine".to_string(),
        Writer::Worker(i) => format!("worker{i}"),
        Writer::Stage(s) => format!("stage{s}"),
    }
}

fn tid(w: Writer) -> u32 {
    match w {
        Writer::Engine => 1,
        Writer::Worker(i) => 10 + u32::from(i),
        Writer::Stage(s) => 1000 + u32::from(s),
    }
}

fn side_label(side: KvKind) -> &'static str {
    match side {
        KvKind::Key => "key",
        KvKind::Value => "value",
    }
}

fn reason_label(reason: FinishClass) -> &'static str {
    match reason {
        FinishClass::Stop => "stop",
        FinishClass::Length => "length",
        FinishClass::Oom => "oom",
    }
}

fn phase_label(phase: SweepPhase) -> &'static str {
    match phase {
        SweepPhase::Reserve => "reserve",
        SweepPhase::Prefill => "prefill",
        SweepPhase::Decode => "decode",
        SweepPhase::Flush => "flush",
    }
}

/// Finite floats render as plain decimals (Rust's `Display` never emits
/// exponents, so the output is always a valid JSON number); non-finite
/// values — which the quality probe never produces for real inputs —
/// degrade to `null` rather than corrupting the document.
fn fmt_f32(x: f32) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Append this kind's payload fields (each preceded by a comma) in the
/// exact order [`KIND_FIELDS`] declares for it.
fn push_fields(out: &mut String, kind: &EventKind) {
    match *kind {
        EventKind::Enqueue { req_id } => {
            let _ = write!(out, ",\"req_id\":{req_id}");
        }
        EventKind::Admit { serial, req_id } => {
            let _ = write!(out, ",\"serial\":{serial},\"req_id\":{req_id}");
        }
        EventKind::Reserve { serial, bytes } => {
            let _ = write!(out, ",\"serial\":{serial},\"bytes\":{bytes}");
        }
        EventKind::PrefillChunk { serial, rows } => {
            let _ = write!(out, ",\"serial\":{serial},\"rows\":{rows}");
        }
        EventKind::PlaneChosen { batch, pipelined } => {
            let _ = write!(out, ",\"batch\":{batch},\"pipelined\":{pipelined}");
        }
        EventKind::DecodeStep { n_seqs } => {
            let _ = write!(out, ",\"n_seqs\":{n_seqs}");
        }
        EventKind::FirstToken { serial } => {
            let _ = write!(out, ",\"serial\":{serial}");
        }
        EventKind::Seal { serial, layer, rows } => {
            let _ = write!(out, ",\"serial\":{serial},\"layer\":{layer},\"rows\":{rows}");
        }
        EventKind::FlushSubmit { serial, layer, rows } => {
            let _ = write!(out, ",\"serial\":{serial},\"layer\":{layer},\"rows\":{rows}");
        }
        EventKind::FlushJoin { serial, layer } => {
            let _ = write!(out, ",\"serial\":{serial},\"layer\":{layer}");
        }
        EventKind::Preempt { serial, oom } => {
            let _ = write!(out, ",\"serial\":{serial},\"oom\":{oom}");
        }
        EventKind::Finish { serial, reason, tokens } => {
            let _ = write!(
                out,
                ",\"serial\":{serial},\"reason\":\"{}\",\"tokens\":{tokens}",
                reason_label(reason)
            );
        }
        EventKind::Quality(q) => {
            let _ = write!(
                out,
                ",\"serial\":{},\"layer\":{},\"rows\":{},\"prefill\":{},\"side\":\"{}\",\
                 \"bytes\":{},\"pred_bytes\":{},\"err_fro\":{},\"quant_resid_fro\":{},\
                 \"lowrank_fro\":{},\"outlier_fro\":{}",
                q.serial,
                q.layer,
                q.rows,
                q.prefill,
                side_label(q.side),
                q.bytes,
                q.pred_bytes,
                fmt_f32(q.err_fro),
                fmt_f32(q.quant_resid_fro),
                fmt_f32(q.lowrank_fro),
                fmt_f32(q.outlier_fro)
            );
        }
        EventKind::Phase { phase } => {
            let _ = write!(out, ",\"phase\":\"{}\"", phase_label(phase));
        }
        EventKind::Chunk { n_seqs } => {
            let _ = write!(out, ",\"n_seqs\":{n_seqs}");
        }
        EventKind::StageSpan { stage, busy } => {
            let _ = write!(out, ",\"stage\":{stage},\"busy\":{busy}");
        }
        EventKind::FlushRun { layer } => {
            let _ = write!(out, ",\"layer\":{layer}");
        }
    }
}

/// The journal's first line: a `schema` object declaring the base keys
/// and the payload keys of every event kind, mirroring the pattern of
/// `BENCH_throughput.json`'s `schema` object.
pub fn jsonl_schema_line() -> String {
    let mut s = String::from("{\"schema\":{\"version\":1,\"base\":[");
    for (i, k) in BASE_FIELDS.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "\"{k}\"");
    }
    s.push_str("],\"kinds\":{");
    for (i, (kind, fields)) in KIND_FIELDS.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "\"{kind}\":[");
        for (j, f) in fields.iter().enumerate() {
            if j > 0 {
                s.push(',');
            }
            let _ = write!(s, "\"{f}\"");
        }
        s.push(']');
    }
    s.push_str("}}}");
    s
}

fn jsonl_line(ev: &Event) -> String {
    let mut s = format!(
        "{{\"t_ns\":{},\"dur_ns\":{},\"writer\":\"{}\",\"kind\":\"{}\"",
        ev.t_ns,
        ev.dur_ns,
        writer_label(ev.writer),
        ev.kind.name()
    );
    push_fields(&mut s, &ev.kind);
    s.push('}');
    s
}

/// Render the JSONL journal: schema line first, then one event per line
/// in emission/fold order.
pub fn render_jsonl(events: &[Event]) -> String {
    let mut out = jsonl_schema_line();
    out.push('\n');
    for ev in events {
        out.push_str(&jsonl_line(ev));
        out.push('\n');
    }
    out
}

/// Display name for the Perfetto track entry.
fn display_name(kind: &EventKind) -> String {
    match kind {
        EventKind::Phase { phase } => format!("phase:{}", phase_label(*phase)),
        EventKind::StageSpan { stage, busy } => {
            format!("stage{stage}:{}", if *busy { "busy" } else { "bubble" })
        }
        EventKind::FlushRun { layer } => format!("flush_run:L{layer}"),
        _ => kind.name().to_string(),
    }
}

/// Render a Chrome-trace / Perfetto JSON document. Logical events
/// become thread-scoped instants, timing events become complete (`X`)
/// spans; the engine, each worker, and each pipeline stage get named
/// tracks via `thread_name` metadata. Timestamps are normalised to the
/// earliest event and expressed in microseconds.
pub fn render_perfetto(events: &[Event]) -> String {
    let t0 = events.iter().map(|e| e.t_ns).min().unwrap_or(0);
    let mut order: Vec<usize> = (0..events.len()).collect();
    order.sort_by_key(|&i| (events[i].t_ns, i));
    let mut tracks: Vec<Writer> = events.iter().map(|e| e.writer).collect();
    tracks.sort();
    tracks.dedup();

    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    let mut first = true;
    let mut sep = |out: &mut String| {
        if first {
            first = false;
        } else {
            out.push_str(",\n");
        }
    };
    for &w in &tracks {
        sep(&mut out);
        let _ = write!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            tid(w),
            writer_label(w)
        );
    }
    for &i in &order {
        let ev = &events[i];
        sep(&mut out);
        let ts = (ev.t_ns - t0) as f64 / 1000.0;
        let mut fields = String::new();
        push_fields(&mut fields, &ev.kind);
        let args = fields.strip_prefix(',').unwrap_or("");
        if ev.kind.is_logical() {
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{ts:.3},\"pid\":1,\
                 \"tid\":{},\"args\":{{{args}}}}}",
                display_name(&ev.kind),
                tid(ev.writer)
            );
        } else {
            let dur = ev.dur_ns as f64 / 1000.0;
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{ts:.3},\"dur\":{dur:.3},\"pid\":1,\
                 \"tid\":{},\"args\":{{{args}}}}}",
                display_name(&ev.kind),
                tid(ev.writer)
            );
        }
    }
    out.push_str("\n]}\n");
    out
}

/// Write `contents` to `path` atomically: a pid-keyed temp file in the
/// same directory, then a rename. Parallel test processes sharing one
/// `GEAR_TRACE` path each land a complete document instead of
/// interleaved partial writes.
pub(crate) fn write_atomic(path: &Path, contents: &str) -> io::Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp.{}", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    fs::write(&tmp, contents)?;
    fs::rename(&tmp, path)
}

impl Tracer {
    /// Export the recorded run: Perfetto JSON to the configured path and
    /// the JSONL journal next to it (extension swapped to `.jsonl`).
    /// No-op for capture-only tracers.
    pub fn export_files(&self) -> io::Result<()> {
        let Some(path) = self.path() else {
            return Ok(());
        };
        write_atomic(path, &render_perfetto(self.events()))?;
        write_atomic(&path.with_extension("jsonl"), &render_jsonl(self.events()))
    }
}

// ---------------------------------------------------------------------------
// Validating parser
// ---------------------------------------------------------------------------

/// Minimal JSON value, produced by [`parse_json`].
#[derive(Debug, Clone, PartialEq)]
pub enum JsonVal {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, as `f64`.
    Num(f64),
    /// A string (escape-free by construction of our emitters).
    Str(String),
    /// An array.
    Arr(Vec<JsonVal>),
    /// An object, preserving key order.
    Obj(Vec<(String, JsonVal)>),
}

impl JsonVal {
    /// Member lookup on an object.
    pub fn get(&self, key: &str) -> Option<&JsonVal> {
        match self {
            JsonVal::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonVal::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[JsonVal]> {
        match self {
            JsonVal::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object payload, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, JsonVal)]> {
        match self {
            JsonVal::Obj(o) => Some(o),
            _ => None,
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'"' => {
                    let s = std::str::from_utf8(&self.s[start..self.pos])
                        .map_err(|_| "invalid utf-8 in string".to_string())?
                        .to_string();
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => return Err(format!("escape sequence at byte {} unsupported", self.pos)),
                _ => self.pos += 1,
            }
        }
        Err("unterminated string".to_string())
    }

    fn number(&mut self) -> Result<f64, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).unwrap_or("");
        text.parse::<f64>().map_err(|_| format!("bad number {text:?} at byte {start}"))
    }

    fn literal(&mut self, lit: &str, val: JsonVal) -> Result<JsonVal, String> {
        if self.s[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonVal, String> {
        self.ws();
        match self.peek() {
            Some(b'"') => Ok(JsonVal::Str(self.string()?)),
            Some(b'{') => {
                self.pos += 1;
                let mut kvs = Vec::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(JsonVal::Obj(kvs));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.expect(b':')?;
                    let v = self.value()?;
                    kvs.push((k, v));
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(JsonVal::Obj(kvs));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
                    }
                }
            }
            Some(b'[') => {
                self.pos += 1;
                let mut vals = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(JsonVal::Arr(vals));
                }
                loop {
                    vals.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(JsonVal::Arr(vals));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
                    }
                }
            }
            Some(b't') => self.literal("true", JsonVal::Bool(true)),
            Some(b'f') => self.literal("false", JsonVal::Bool(false)),
            Some(b'n') => self.literal("null", JsonVal::Null),
            Some(_) => Ok(JsonVal::Num(self.number()?)),
            None => Err("unexpected end of input".to_string()),
        }
    }
}

/// Parse one JSON document (the escape-free subset our emitters
/// produce). Trailing garbage after the document is an error.
pub fn parse_json(text: &str) -> Result<JsonVal, String> {
    let mut p = Parser { s: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.ws();
    if p.pos != p.s.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

/// Validate a JSONL journal against the schema declared on its first
/// line: every event line must parse, carry the base keys in order,
/// name a kind the schema declares, and carry exactly that kind's
/// payload keys in order. Returns the number of event lines.
pub fn validate_jsonl(text: &str) -> Result<usize, String> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| "empty journal".to_string())?;
    let header = parse_json(header).map_err(|e| format!("schema line: {e}"))?;
    let schema = header.get("schema").ok_or_else(|| "first line lacks \"schema\"".to_string())?;
    let base: Vec<&str> = schema
        .get("base")
        .and_then(JsonVal::as_arr)
        .ok_or_else(|| "schema.base missing".to_string())?
        .iter()
        .map(|v| v.as_str().ok_or_else(|| "schema.base entry not a string".to_string()))
        .collect::<Result<_, _>>()?;
    let kinds = schema
        .get("kinds")
        .and_then(JsonVal::as_obj)
        .ok_or_else(|| "schema.kinds missing".to_string())?;

    let mut n = 0usize;
    for (i, line) in lines.enumerate() {
        let ctx = |e: String| format!("event line {}: {e}", i + 1);
        let v = parse_json(line).map_err(&ctx)?;
        let obj = v.as_obj().ok_or_else(|| ctx("not an object".to_string()))?;
        let keys: Vec<&str> = obj.iter().map(|(k, _)| k.as_str()).collect();
        if keys.len() < base.len() || keys[..base.len()] != base[..] {
            return Err(ctx(format!("base keys {:?} != {base:?}", &keys)));
        }
        for k in &base {
            let val = v.get(k).expect("base key present");
            let ok = match *k {
                "t_ns" | "dur_ns" => matches!(val, JsonVal::Num(_)),
                "writer" | "kind" => matches!(val, JsonVal::Str(_)),
                _ => true,
            };
            if !ok {
                return Err(ctx(format!("base key {k:?} has wrong type")));
            }
        }
        let kind = v.get("kind").and_then(JsonVal::as_str).expect("checked above");
        let declared = kinds
            .iter()
            .find(|(k, _)| k == kind)
            .ok_or_else(|| ctx(format!("kind {kind:?} not in schema")))?;
        let expected: Vec<&str> = declared
            .1
            .as_arr()
            .ok_or_else(|| ctx(format!("schema.kinds[{kind:?}] not an array")))?
            .iter()
            .map(|f| f.as_str().unwrap_or("?"))
            .collect();
        if keys[base.len()..] != expected[..] {
            return Err(ctx(format!(
                "kind {kind:?} payload keys {:?} != declared {expected:?}",
                &keys[base.len()..]
            )));
        }
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Quality;

    /// One event of every kind, exercising every serializer arm.
    fn one_of_each() -> Vec<Event> {
        let kinds = vec![
            EventKind::Enqueue { req_id: 1 },
            EventKind::Admit { serial: 0, req_id: 1 },
            EventKind::Reserve { serial: 0, bytes: 4096 },
            EventKind::PrefillChunk { serial: 0, rows: 32 },
            EventKind::PlaneChosen { batch: 2, pipelined: true },
            EventKind::DecodeStep { n_seqs: 2 },
            EventKind::FirstToken { serial: 0 },
            EventKind::Seal { serial: 0, layer: 1, rows: 16 },
            EventKind::FlushSubmit { serial: 0, layer: 1, rows: 16 },
            EventKind::FlushJoin { serial: 0, layer: 1 },
            EventKind::Preempt { serial: 3, oom: false },
            EventKind::Finish { serial: 0, reason: FinishClass::Length, tokens: 24 },
            EventKind::Quality(Quality {
                serial: 0,
                layer: 1,
                rows: 16,
                prefill: false,
                side: KvKind::Key,
                bytes: 512,
                pred_bytes: 512,
                err_fro: 0.25,
                quant_resid_fro: 0.5,
                lowrank_fro: 0.4,
                outlier_fro: 0.0,
            }),
            EventKind::Phase { phase: SweepPhase::Decode },
            EventKind::Chunk { n_seqs: 2 },
            EventKind::StageSpan { stage: 0, busy: true },
            EventKind::FlushRun { layer: 1 },
        ];
        kinds
            .into_iter()
            .enumerate()
            .map(|(i, kind)| Event {
                t_ns: 1000 + i as u64,
                dur_ns: if kind.is_logical() { 0 } else { 50 },
                writer: match i % 3 {
                    0 => Writer::Engine,
                    1 => Writer::Worker(2),
                    _ => Writer::Stage(1),
                },
                kind,
            })
            .collect()
    }

    #[test]
    fn every_kind_round_trips_through_the_validator() {
        let events = one_of_each();
        assert_eq!(events.len(), KIND_FIELDS.len(), "one sample per declared kind");
        let jsonl = render_jsonl(&events);
        let n = validate_jsonl(&jsonl).expect("schema-valid journal");
        assert_eq!(n, events.len());
    }

    #[test]
    fn validator_rejects_undeclared_keys_and_kinds() {
        let good = render_jsonl(&one_of_each());
        let mut lines: Vec<&str> = good.lines().collect();
        let bad_kind = "{\"t_ns\":1,\"dur_ns\":0,\"writer\":\"engine\",\"kind\":\"bogus\"}";
        lines.push(bad_kind);
        assert!(validate_jsonl(&lines.join("\n")).unwrap_err().contains("bogus"));

        let mut lines: Vec<&str> = good.lines().collect();
        let extra_key =
            "{\"t_ns\":1,\"dur_ns\":0,\"writer\":\"engine\",\"kind\":\"first_token\",\
             \"serial\":0,\"smuggled\":1}";
        lines.push(extra_key);
        assert!(validate_jsonl(&lines.join("\n")).is_err());

        // Journal without a schema line fails immediately.
        assert!(validate_jsonl("{\"t_ns\":0}").is_err());
    }

    #[test]
    fn perfetto_document_parses_and_names_tracks() {
        let doc = render_perfetto(&one_of_each());
        let v = parse_json(&doc).expect("valid JSON");
        let evs = v.get("traceEvents").and_then(JsonVal::as_arr).expect("traceEvents array");
        // 3 distinct writers -> 3 thread_name metadata entries + the events.
        assert_eq!(evs.len(), 3 + KIND_FIELDS.len());
        let meta: Vec<&JsonVal> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(JsonVal::as_str) == Some("M"))
            .collect();
        assert_eq!(meta.len(), 3);
        let names: Vec<&str> = meta
            .iter()
            .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(JsonVal::as_str))
            .collect();
        assert!(names.contains(&"engine"));
        assert!(names.contains(&"worker2"));
        assert!(names.contains(&"stage1"));
        // Spans carry durations, instants don't.
        assert!(evs.iter().any(|e| e.get("ph").and_then(JsonVal::as_str) == Some("X")));
        assert!(evs.iter().any(|e| e.get("ph").and_then(JsonVal::as_str) == Some("i")));
    }

    #[test]
    fn schema_line_is_valid_json_and_covers_all_kinds() {
        let v = parse_json(&jsonl_schema_line()).expect("valid JSON");
        let kinds = v.get("schema").and_then(|s| s.get("kinds")).and_then(JsonVal::as_obj);
        assert_eq!(kinds.map(|k| k.len()), Some(KIND_FIELDS.len()));
    }

    #[test]
    fn parser_handles_nested_values_and_rejects_trailing_data() {
        let v = parse_json("{\"a\":[1,2.5,-3e2],\"b\":{\"c\":null,\"d\":true}}").unwrap();
        assert_eq!(v.get("a").and_then(JsonVal::as_arr).map(|a| a.len()), Some(3));
        assert_eq!(v.get("b").and_then(|b| b.get("d")), Some(&JsonVal::Bool(true)));
        assert!(parse_json("{} junk").is_err());
        assert!(parse_json("{\"unterminated").is_err());
        assert!(parse_json("{\"esc\":\"a\\nb\"}").is_err());
    }
}
