//! Structured engine tracing: per-thread event rings, request lifecycle
//! events, sweep-phase spans, and GEAR quality telemetry.
//!
//! ## Ring ownership and the hot-path contract
//!
//! Every thread that can observe engine work owns at most one
//! pre-allocated event ring, stored in a thread local:
//!
//! * the **engine thread** writes through its [`Tracer`] (created per
//!   engine when tracing is enabled), which owns the largest ring and is
//!   the single point where all events are eventually folded;
//! * each **pool worker** lazily allocates a thread-local ring the first
//!   time it emits a traced event and drains it into a caller-owned slot
//!   at the end of every chunk / stage / flush it runs — the fold points
//!   mirror [`crate::gear::take_phase_timings`], so no cross-thread
//!   channel or shared lock ever appears on the emission path.
//!
//! When tracing is **off** the cost model is strict: no ring is
//! allocated anywhere (asserted by [`rings_allocated`] in
//! `tests/trace_golden.rs`), no lock is taken, and the only residue on
//! the hot path is a single relaxed atomic load per potential emission
//! site (the executor caches even that in a plain `bool` per sweep).
//!
//! ## Logical vs. timing events
//!
//! [`EventKind`] splits into two families:
//!
//! * **logical** events (`EventKind::is_logical`) are emitted by the
//!   engine thread at policy commit points — admission, reservation,
//!   prefill-chunk layout, seal/submit/join of segment flushes,
//!   preemption, first token, finish, and per-layer GEAR [`Quality`]
//!   records. Their payloads carry no timing data. Because the policy
//!   plane is deterministic by construction, the logical stream is
//!   **bit-identical across [`crate::coordinator::ExecMode`]s and pool
//!   sizes** — `tests/trace_golden.rs` enforces this as a cross-plane
//!   oracle on top of the token-stream goldens.
//! * **timing** events (phase / chunk / stage / flush-run spans) record
//!   where wall time went. Their count and interleaving legitimately
//!   depend on pool width and mode, so they are excluded from the
//!   golden comparison.
//!
//! ## Export
//!
//! [`Tracer::export_files`] (in [`export`]) writes a Chrome-trace /
//! Perfetto JSON (workers and stages as named tracks) plus a JSONL
//! journal whose first line declares the schema, in the same spirit as
//! `BENCH_throughput.json`'s `schema` object. [`Tracer::summary`] folds
//! an aggregate [`TraceSummary`] into
//! [`crate::coordinator::EngineMetrics`].

pub mod export;

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::gear::KvKind;

/// Capacity of a worker's lazily-allocated thread-local ring. Workers
/// drain at every chunk/stage/flush boundary, so this only needs to hold
/// one fold interval's worth of events.
const WORKER_RING_CAP: usize = 4096;

/// Capacity of the engine [`Tracer`] ring, which holds a whole run.
const ENGINE_RING_CAP: usize = 1 << 16;

/// Process-wide count of live [`Tracer`]s. The single relaxed load of
/// this counter is the documented tracing-off cost on shared code paths
/// (e.g. the quality probe inside `gear::compose::compress`).
static ACTIVE_TRACERS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of rings ever allocated (engine + thread-local).
/// Monotonic; the disabled-mode test asserts it does not move.
static RINGS_ALLOCATED: AtomicU64 = AtomicU64::new(0);

/// Events discarded because a ring was full (drop-new policy).
static EVENTS_DROPPED: AtomicU64 = AtomicU64::new(0);

/// Common time origin for every ring in the process, so events from
/// different threads land on one comparable axis.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Number of event rings ever allocated in this process (monotonic).
/// A run with tracing disabled must leave this unchanged.
pub fn rings_allocated() -> u64 {
    RINGS_ALLOCATED.load(Ordering::Relaxed)
}

/// True when at least one [`Tracer`] is alive anywhere in the process.
/// One relaxed atomic load — the entire tracing-off cost at call sites
/// that cannot see an engine-owned flag.
pub(crate) fn tracing_active() -> bool {
    ACTIVE_TRACERS.load(Ordering::Relaxed) > 0
}

/// Nanoseconds since the process-wide trace epoch.
pub(crate) fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Which track an event belongs to. Rings are owned per *thread*;
/// writers are the logical tracks events are attributed to (a pool
/// worker executing a pipeline stage emits that stage's span with a
/// [`Writer::Stage`] writer from its own thread-local ring).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Writer {
    /// The engine (policy) thread.
    Engine,
    /// Pool worker `i` (thread `gear-exec-i`).
    Worker(u16),
    /// Pipeline stage `s` of the layer-sharded decode plane.
    Stage(u16),
}

/// Why a request finished, as recorded in the trace. Mirrors
/// [`crate::coordinator::FinishReason`] without the payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishClass {
    /// Hit a stop token.
    Stop,
    /// Hit `max_new_tokens`.
    Length,
    /// Evicted terminally or rejected at admission for byte budget.
    Oom,
}

/// The engine sweep phase a timing span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepPhase {
    /// Byte reservation / preemption loop.
    Reserve,
    /// Chunked prefill round.
    Prefill,
    /// Batched decode step.
    Decode,
    /// Joining last sweep's flush tickets.
    Flush,
}

/// One per-matrix GEAR quality record: achieved bytes vs.
/// [`crate::gear::size::predict`], plus the Frobenius norms of the
/// Eq. (4) components so the per-layer error budget is visible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quality {
    /// Admission serial of the request whose segment was compressed.
    pub serial: u64,
    /// Layer index of the cache the segment belongs to.
    pub layer: u32,
    /// Token rows in the compressed segment.
    pub rows: u32,
    /// True for prefill (rank `r_p`) compression, false for a sealed
    /// decode-buffer flush (rank `r_g`).
    pub prefill: bool,
    /// Key or Value matrix.
    pub side: KvKind,
    /// Achieved compressed size in bytes (`CompressedMatrix::nbytes`).
    pub bytes: u64,
    /// Predicted size from `gear::size::predict` (exact by contract).
    pub pred_bytes: u64,
    /// `‖X − (D̂ + L + S)‖_F` — total reconstruction error.
    pub err_fro: f32,
    /// `‖X − D̂ − S‖_F` — the residual the low-rank term approximates.
    pub quant_resid_fro: f32,
    /// `‖L‖_F` — energy captured by the low-rank term.
    pub lowrank_fro: f32,
    /// `‖S‖_F` — energy carried by the sparse outliers.
    pub outlier_fro: f32,
}

impl Quality {
    /// Attach request/layer identity to a staged observation at its
    /// deterministic drain point (prefill commit or flush join).
    pub(crate) fn from_staged(
        q: &QualityStaged,
        serial: u64,
        layer: u32,
        prefill: bool,
    ) -> Quality {
        Quality {
            serial,
            layer,
            rows: q.rows,
            prefill,
            side: q.side,
            bytes: q.bytes,
            pred_bytes: q.pred_bytes,
            err_fro: q.err_fro,
            quant_resid_fro: q.quant_resid_fro,
            lowrank_fro: q.lowrank_fro,
            outlier_fro: q.outlier_fro,
        }
    }
}

/// A trace event payload. Logical kinds (see [`EventKind::is_logical`])
/// form the mode-independent golden stream; timing kinds are spans whose
/// shape depends on pool width and exec mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Request handed to [`crate::coordinator::Engine::submit`].
    Enqueue {
        /// Caller-assigned request id.
        req_id: u64,
    },
    /// Scheduler admitted the request and assigned its serial.
    Admit {
        /// Admission serial (total order over admissions).
        serial: u64,
        /// Caller-assigned request id.
        req_id: u64,
    },
    /// Byte reservation for one active request this sweep.
    Reserve {
        /// Admission serial.
        serial: u64,
        /// Bytes reserved (current footprint + step growth bound).
        bytes: u64,
    },
    /// One prefill chunk scheduled for a request this sweep.
    PrefillChunk {
        /// Admission serial.
        serial: u64,
        /// Prompt rows in this chunk.
        rows: u32,
    },
    /// `ExecMode::Hybrid` plane selection for one decode sweep, emitted
    /// just before the sweep's [`EventKind::DecodeStep`]. Logical: the
    /// policy reads only the deterministic decode-batch sequence, so the
    /// chosen sequence is identical for a given threshold across pool
    /// sizes and stage counts.
    PlaneChosen {
        /// The deciding decode batch size.
        batch: u32,
        /// True when the sweep dispatched through the pipelined plane,
        /// false for the batch-chunked plane.
        pipelined: bool,
    },
    /// One batched decode step over the active set.
    DecodeStep {
        /// Sequences decoded this step.
        n_seqs: u32,
    },
    /// First generated token committed for a request.
    FirstToken {
        /// Admission serial.
        serial: u64,
    },
    /// A streaming-buffer segment sealed and detached for compression.
    Seal {
        /// Admission serial.
        serial: u64,
        /// Layer index.
        layer: u32,
        /// Rows in the sealed segment.
        rows: u32,
    },
    /// Sealed segment submitted to the flush lane.
    FlushSubmit {
        /// Admission serial.
        serial: u64,
        /// Layer index.
        layer: u32,
        /// Rows in the submitted segment.
        rows: u32,
    },
    /// Flush ticket joined; compressed segment installed at commit.
    FlushJoin {
        /// Admission serial.
        serial: u64,
        /// Layer index.
        layer: u32,
    },
    /// Scheduler preempted the youngest active request.
    Preempt {
        /// Admission serial of the victim.
        serial: u64,
        /// True if the victim could not be requeued and finished OOM.
        oom: bool,
    },
    /// Request left the active set.
    Finish {
        /// Admission serial.
        serial: u64,
        /// Why it finished.
        reason: FinishClass,
        /// Generated tokens at finish.
        tokens: u32,
    },
    /// Per-matrix GEAR quality record (see [`Quality`]).
    Quality(Quality),
    /// Timing: one engine sweep phase (engine thread).
    Phase {
        /// Which phase the span covers.
        phase: SweepPhase,
    },
    /// Timing: one decode/prefill chunk executed by a pool worker.
    Chunk {
        /// Sequences (decode) or slots (prefill) in the chunk.
        n_seqs: u32,
    },
    /// Timing: a pipeline stage interval — busy (executing its layer
    /// range) or a bubble (waiting on the upstream hand-off).
    StageSpan {
        /// Stage index.
        stage: u16,
        /// True for busy execution, false for a hand-off bubble.
        busy: bool,
    },
    /// Timing: the worker-side run of one submitted flush job.
    FlushRun {
        /// Layer index of the flushed cache.
        layer: u32,
    },
}

impl EventKind {
    /// Whether this kind belongs to the deterministic logical stream
    /// (true) or to the mode-dependent timing family (false).
    pub fn is_logical(&self) -> bool {
        !matches!(
            self,
            EventKind::Phase { .. }
                | EventKind::Chunk { .. }
                | EventKind::StageSpan { .. }
                | EventKind::FlushRun { .. }
        )
    }

    /// Stable snake_case name used by both export formats and the JSONL
    /// schema object.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Enqueue { .. } => "enqueue",
            EventKind::Admit { .. } => "admit",
            EventKind::Reserve { .. } => "reserve",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::PlaneChosen { .. } => "plane_chosen",
            EventKind::DecodeStep { .. } => "decode_step",
            EventKind::FirstToken { .. } => "first_token",
            EventKind::Seal { .. } => "seal",
            EventKind::FlushSubmit { .. } => "flush_submit",
            EventKind::FlushJoin { .. } => "flush_join",
            EventKind::Preempt { .. } => "preempt",
            EventKind::Finish { .. } => "finish",
            EventKind::Quality(_) => "quality",
            EventKind::Phase { .. } => "phase",
            EventKind::Chunk { .. } => "chunk",
            EventKind::StageSpan { .. } => "stage_span",
            EventKind::FlushRun { .. } => "flush_run",
        }
    }
}

/// One recorded event: a payload plus the track it belongs to and its
/// position (and, for spans, extent) on the shared time axis.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Nanoseconds since the process trace epoch.
    pub t_ns: u64,
    /// Span duration in nanoseconds; 0 for instant events.
    pub dur_ns: u64,
    /// Logical track.
    pub writer: Writer,
    /// Payload.
    pub kind: EventKind,
}

/// Fixed-capacity event buffer. Pushes past capacity are dropped (the
/// *new* event is discarded so the recorded prefix stays contiguous) and
/// counted in the process-wide drop counter.
#[derive(Debug)]
struct Ring {
    buf: Vec<Event>,
}

impl Ring {
    fn with_capacity(cap: usize) -> Self {
        RINGS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
        Ring { buf: Vec::with_capacity(cap) }
    }

    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(ev);
        } else {
            EVENTS_DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Take the buffered events, keeping the allocation.
    fn drain(&mut self) -> Vec<Event> {
        self.buf.drain(..).collect()
    }
}

/// One GEAR quality observation staged by `gear::compose::compress`
/// before the caller can attribute it to a (serial, layer). The engine
/// (prefill commit) or flush lane (segment compression) drains these in
/// deterministic order — K then V per layer — and attaches identity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QualityStaged {
    /// Key or Value matrix.
    pub side: KvKind,
    /// Token rows compressed.
    pub rows: u32,
    /// Channels.
    pub cols: u32,
    /// Achieved compressed bytes.
    pub bytes: u64,
    /// Predicted bytes from `gear::size::predict`.
    pub pred_bytes: u64,
    /// `‖X − (D̂ + L + S)‖_F`.
    pub err_fro: f32,
    /// `‖X − D̂ − S‖_F`.
    pub quant_resid_fro: f32,
    /// `‖L‖_F`.
    pub lowrank_fro: f32,
    /// `‖S‖_F`.
    pub outlier_fro: f32,
}

struct TlState {
    writer: Writer,
    ring: Option<Ring>,
    quality_on: bool,
    staged: Vec<QualityStaged>,
}

thread_local! {
    static TL: RefCell<TlState> = RefCell::new(TlState {
        writer: Writer::Engine,
        ring: None,
        quality_on: false,
        staged: Vec::new(),
    });
}

/// Declare which logical track this thread's emissions belong to.
/// Called once by each pool worker at thread start; allocates nothing.
pub(crate) fn set_thread_writer(w: Writer) {
    TL.with(|tl| tl.borrow_mut().writer = w);
}

/// This thread's declared track ([`Writer::Engine`] if never declared).
pub(crate) fn thread_writer() -> Writer {
    TL.with(|tl| tl.borrow().writer)
}

/// Emit a span that started at `start_ns` and ends now, optionally
/// attributed to an explicit writer (e.g. a stage track) instead of the
/// thread default.
pub(crate) fn emit_thread_span(writer: Option<Writer>, kind: EventKind, start_ns: u64) {
    let now = now_ns();
    emit_thread_raw(writer, kind, start_ns, now.saturating_sub(start_ns));
}

/// Emit an event at an explicit position/extent on the time axis (used
/// for the pipeline plane's aggregate busy/bubble placement).
pub(crate) fn emit_thread_at(writer: Option<Writer>, kind: EventKind, t_ns: u64, dur_ns: u64) {
    emit_thread_raw(writer, kind, t_ns, dur_ns);
}

fn emit_thread_raw(writer: Option<Writer>, kind: EventKind, t_ns: u64, dur_ns: u64) {
    TL.with(|tl| {
        let mut tl = tl.borrow_mut();
        let w = writer.unwrap_or(tl.writer);
        let ring = tl.ring.get_or_insert_with(|| Ring::with_capacity(WORKER_RING_CAP));
        ring.push(Event { t_ns, dur_ns, writer: w, kind });
    });
}

/// Drain this thread's ring. Workers call this at every fold point
/// (end of chunk / stage / flush) so their events travel back to the
/// engine through the same caller-owned slots as the phase timers.
pub(crate) fn drain_thread() -> Vec<Event> {
    TL.with(|tl| tl.borrow_mut().ring.as_mut().map(Ring::drain).unwrap_or_default())
}

/// Whether `gear::compose::compress` should stage a quality record.
/// Costs one relaxed atomic load when no tracer exists in the process;
/// the thread-local flag is only consulted after that fast-out.
pub(crate) fn quality_capture_on() -> bool {
    tracing_active() && TL.with(|tl| tl.borrow().quality_on)
}

/// Scope the quality probe for compress calls on this thread. Set only
/// around attributable compressions (prefill commit, flush run) so
/// unrelated compress calls never stage records.
pub(crate) fn set_quality_capture(on: bool) {
    TL.with(|tl| tl.borrow_mut().quality_on = on);
}

/// Stage one quality observation on this thread (identity attached
/// later by whoever drains it).
pub(crate) fn stage_quality(q: QualityStaged) {
    TL.with(|tl| tl.borrow_mut().staged.push(q));
}

/// Take every staged quality observation on this thread.
pub(crate) fn take_staged_quality() -> Vec<QualityStaged> {
    TL.with(|tl| std::mem::take(&mut tl.borrow_mut().staged))
}

/// Aggregate of one traced run, folded into
/// [`crate::coordinator::EngineMetrics`] and rendered by the server's
/// plain-text `metrics` verb.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TraceSummary {
    /// Total events recorded (logical + timing).
    pub events: u64,
    /// Logical events among them.
    pub logical_events: u64,
    /// Events discarded to full rings during the run.
    pub dropped: u64,
    /// Quality records discarded because attribution was ambiguous.
    pub quality_dropped: u64,
    /// Requests admitted.
    pub admitted: u64,
    /// Preemption events.
    pub preemptions: u64,
    /// Flush tickets joined.
    pub flushes: u64,
    /// Requests finished (any reason).
    pub finished: u64,
    /// Requests finished out-of-memory.
    pub oom_finished: u64,
    /// Quality records captured.
    pub quality_records: u64,
    /// Sum of achieved compressed bytes over quality records.
    pub bytes_actual: u64,
    /// Sum of predicted bytes over quality records.
    pub bytes_predicted: u64,
    /// Largest per-matrix reconstruction error `‖X − X̂‖_F`.
    pub max_err_fro: f32,
    /// Mean per-matrix reconstruction error.
    pub mean_err_fro: f32,
}

/// Engine-side trace collector: the engine thread's ring plus the fold
/// target for worker/stage/flush events. Created per engine when
/// tracing is enabled; its existence flips the process-wide
/// [`tracing_active`] gate.
#[derive(Debug)]
pub struct Tracer {
    ring: Ring,
    path: Option<PathBuf>,
    dropped_at_start: u64,
    quality_dropped: u64,
}

impl Tracer {
    /// Create a tracer. With a path, [`Tracer::export_files`] writes the
    /// Perfetto JSON there and the JSONL journal next to it; without
    /// one the trace is capture-only (used by the golden tests).
    pub fn new(path: Option<PathBuf>) -> Self {
        ACTIVE_TRACERS.fetch_add(1, Ordering::Relaxed);
        Tracer {
            ring: Ring::with_capacity(ENGINE_RING_CAP),
            path,
            dropped_at_start: EVENTS_DROPPED.load(Ordering::Relaxed),
            quality_dropped: 0,
        }
    }

    /// Export target, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Emit an instant event on the engine track.
    pub(crate) fn emit(&mut self, kind: EventKind) {
        self.ring.push(Event { t_ns: now_ns(), dur_ns: 0, writer: Writer::Engine, kind });
    }

    /// Emit an engine-track span that started at `start_ns`.
    pub(crate) fn emit_span(&mut self, kind: EventKind, start_ns: u64) {
        let now = now_ns();
        self.ring.push(Event {
            t_ns: start_ns,
            dur_ns: now.saturating_sub(start_ns),
            writer: Writer::Engine,
            kind,
        });
    }

    /// Append events drained from a worker/stage/flush fold point. The
    /// fold positions are deterministic (fixed points in the sweep), so
    /// the journal order is reproducible even though folded timestamps
    /// predate neighbouring engine events.
    pub(crate) fn fold(&mut self, events: Vec<Event>) {
        for ev in events {
            self.ring.push(ev);
        }
    }

    /// Count quality records that had to be discarded because their
    /// (serial, layer) attribution was ambiguous.
    pub(crate) fn note_quality_dropped(&mut self, n: u64) {
        self.quality_dropped += n;
    }

    /// All recorded events, in emission/fold order.
    pub fn events(&self) -> &[Event] {
        &self.ring.buf
    }

    /// The logical stream: payloads of logical events in order, with
    /// timestamps stripped. Bit-identical across exec modes and pool
    /// sizes — the golden-test comparison key.
    pub fn logical(&self) -> Vec<EventKind> {
        self.ring.buf.iter().filter(|e| e.kind.is_logical()).map(|e| e.kind).collect()
    }

    /// Fold the recorded run into an aggregate.
    pub fn summary(&self) -> TraceSummary {
        let mut s = TraceSummary {
            events: self.ring.buf.len() as u64,
            dropped: EVENTS_DROPPED
                .load(Ordering::Relaxed)
                .saturating_sub(self.dropped_at_start),
            quality_dropped: self.quality_dropped,
            ..TraceSummary::default()
        };
        let mut err_sum = 0.0f64;
        for ev in &self.ring.buf {
            if ev.kind.is_logical() {
                s.logical_events += 1;
            }
            match ev.kind {
                EventKind::Admit { .. } => s.admitted += 1,
                EventKind::Preempt { .. } => s.preemptions += 1,
                EventKind::FlushJoin { .. } => s.flushes += 1,
                EventKind::Finish { reason, .. } => {
                    s.finished += 1;
                    if reason == FinishClass::Oom {
                        s.oom_finished += 1;
                    }
                }
                EventKind::Quality(q) => {
                    s.quality_records += 1;
                    s.bytes_actual += q.bytes;
                    s.bytes_predicted += q.pred_bytes;
                    s.max_err_fro = s.max_err_fro.max(q.err_fro);
                    err_sum += f64::from(q.err_fro);
                }
                _ => {}
            }
        }
        if s.quality_records > 0 {
            s.mean_err_fro = (err_sum / s.quality_records as f64) as f32;
        }
        s
    }
}

impl Drop for Tracer {
    fn drop(&mut self) {
        ACTIVE_TRACERS.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind) -> Event {
        Event { t_ns: 1, dur_ns: 0, writer: Writer::Engine, kind }
    }

    #[test]
    fn ring_drops_new_events_when_full() {
        let mut r = Ring::with_capacity(2);
        let cap = r.buf.capacity();
        for i in 0..cap + 3 {
            r.push(ev(EventKind::DecodeStep { n_seqs: i as u32 }));
        }
        assert_eq!(r.buf.len(), cap);
        // The retained prefix is the *oldest* events.
        assert_eq!(r.buf[0].kind, EventKind::DecodeStep { n_seqs: 0 });
        let drained = r.drain();
        assert_eq!(drained.len(), cap);
        assert!(r.buf.is_empty());
        // Allocation survives the drain.
        assert_eq!(r.buf.capacity(), cap);
    }

    #[test]
    fn logical_filter_excludes_timing_kinds() {
        let mut t = Tracer::new(None);
        t.emit(EventKind::Admit { serial: 0, req_id: 7 });
        t.emit(EventKind::Phase { phase: SweepPhase::Decode });
        t.emit(EventKind::Chunk { n_seqs: 3 });
        t.emit(EventKind::StageSpan { stage: 1, busy: true });
        t.emit(EventKind::FlushRun { layer: 0 });
        t.emit(EventKind::FirstToken { serial: 0 });
        assert_eq!(
            t.logical(),
            vec![
                EventKind::Admit { serial: 0, req_id: 7 },
                EventKind::FirstToken { serial: 0 },
            ]
        );
        let s = t.summary();
        assert_eq!(s.events, 6);
        assert_eq!(s.logical_events, 2);
        assert_eq!(s.admitted, 1);
    }

    #[test]
    fn summary_aggregates_quality_records() {
        let mut t = Tracer::new(None);
        for (i, err) in [0.5f32, 1.5f32].into_iter().enumerate() {
            t.emit(EventKind::Quality(Quality {
                serial: 3,
                layer: i as u32,
                rows: 16,
                prefill: false,
                side: KvKind::Key,
                bytes: 100,
                pred_bytes: 100,
                err_fro: err,
                quant_resid_fro: 2.0,
                lowrank_fro: 1.0,
                outlier_fro: 0.0,
            }));
        }
        let s = t.summary();
        assert_eq!(s.quality_records, 2);
        assert_eq!(s.bytes_actual, 200);
        assert_eq!(s.bytes_predicted, 200);
        assert_eq!(s.max_err_fro, 1.5);
        assert!((s.mean_err_fro - 1.0).abs() < 1e-6);
    }

    #[test]
    fn thread_local_emission_folds_back_in_order() {
        let t0 = std::thread::spawn(|| {
            set_thread_writer(Writer::Worker(3));
            emit_thread_at(None, EventKind::Chunk { n_seqs: 2 }, now_ns(), 0);
            emit_thread_span(
                Some(Writer::Stage(1)),
                EventKind::StageSpan { stage: 1, busy: true },
                now_ns(),
            );
            drain_thread()
        })
        .join()
        .unwrap();
        assert_eq!(t0.len(), 2);
        assert_eq!(t0[0].writer, Writer::Worker(3));
        assert_eq!(t0[1].writer, Writer::Stage(1));
        let mut tr = Tracer::new(None);
        tr.fold(t0);
        assert_eq!(tr.events().len(), 2);
        // This thread never emitted, so its drain is an allocation-free no-op.
        assert!(drain_thread().is_empty());
    }

    #[test]
    fn quality_staging_round_trips() {
        std::thread::spawn(|| {
            assert!(take_staged_quality().is_empty());
            set_quality_capture(true);
            stage_quality(QualityStaged {
                side: KvKind::Value,
                rows: 8,
                cols: 4,
                bytes: 64,
                pred_bytes: 64,
                err_fro: 0.1,
                quant_resid_fro: 0.2,
                lowrank_fro: 0.05,
                outlier_fro: 0.0,
            });
            set_quality_capture(false);
            let staged = take_staged_quality();
            assert_eq!(staged.len(), 1);
            assert_eq!(staged[0].side, KvKind::Value);
            assert!(take_staged_quality().is_empty());
        })
        .join()
        .unwrap();
    }
}
