//! Minimal dense f32 tensor used throughout the coordinator.
//!
//! This is deliberately small: contiguous row-major storage, explicit shapes,
//! and exactly the operations the serving path needs (GEMM/GEMV, softmax,
//! layernorm, transpose, row slicing). It is *not* a general autodiff array —
//! training happens in JAX at build time; this crate only does inference and
//! compression math.

pub mod ops;

/// Contiguous row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} product {n} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Rng, sigma: f32) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, sigma);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() needs 2-D, got {:?}", self.shape);
        self.shape[0]
    }

    /// Number of cols for a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() needs 2-D, got {:?}", self.shape);
        self.shape[1]
    }

    /// Borrow row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape.to_vec();
        self
    }

    /// Copy rows [lo, hi) of a 2-D tensor into a new tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let c = self.cols();
        assert!(lo <= hi && hi <= self.rows(), "slice {lo}..{hi} of {} rows", self.rows());
        Tensor::new(&[hi - lo, c], self.data[lo * c..hi * c].to_vec())
    }

    /// Copy a column range [lo, hi) of a 2-D tensor.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        assert!(lo <= hi && hi <= c);
        let w = hi - lo;
        let mut out = Tensor::zeros(&[r, w]);
        for i in 0..r {
            out.data[i * w..(i + 1) * w].copy_from_slice(&self.data[i * c + lo..i * c + hi]);
        }
        out
    }

    /// Vertically stack 2-D tensors with equal column counts.
    pub fn vstack(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let c = parts[0].cols();
        let total: usize = parts.iter().map(|p| p.rows()).sum();
        let mut data = Vec::with_capacity(total * c);
        for p in parts {
            assert_eq!(p.cols(), c);
            data.extend_from_slice(&p.data);
        }
        Tensor::new(&[total, c], data)
    }

    pub fn t(&self) -> Tensor {
        ops::transpose(self)
    }
}

impl std::fmt::Display for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn slicing() {
        let t = Tensor::new(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.slice_rows(1, 3).data(), &[3., 4., 5., 6.]);
        let c = t.slice_cols(1, 2);
        assert_eq!(c.shape(), &[3, 1]);
        assert_eq!(c.data(), &[2., 4., 6.]);
    }

    #[test]
    fn vstack_works() {
        let a = Tensor::new(&[1, 2], vec![1., 2.]);
        let b = Tensor::new(&[2, 2], vec![3., 4., 5., 6.]);
        let s = Tensor::vstack(&[&a, &b]);
        assert_eq!(s.shape(), &[3, 2]);
        assert_eq!(s.data(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).reshape(&[3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.row(2), &[5., 6.]);
    }
}
