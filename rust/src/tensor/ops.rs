//! Dense linear-algebra kernels for the serving path.
//!
//! `matmul` is written as a blocked i-k-j loop so LLVM autovectorizes the
//! inner j loop; this is the baseline the §Perf pass iterates on. All
//! routines are allocation-explicit: `_into` variants write into caller
//! scratch so the decode hot loop can run allocation-free.

use super::Tensor;

/// C = A @ B for 2-D tensors.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), m, k, n, out.data_mut());
    out
}

/// Raw GEMM into caller storage: c[m,n] = a[m,k] @ b[k,n].
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // i-k-j ordering: unit-stride access on both b and c rows.
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// C = A @ B^T: c[m,n] = a[m,k] @ b[n,k]^T. Dot-product form, unit stride on
/// both operands — preferred when B is naturally row-major in (n, k).
pub fn matmul_bt_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            c[i * n + j] = dot(arow, brow);
        }
    }
}

/// Dot product, 4-way unrolled for autovectorization.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let mut out = Tensor::zeros(&[n, m]);
    let src = a.data();
    let dst = out.data_mut();
    for i in 0..m {
        for j in 0..n {
            dst[j * m + i] = src[i * n + j];
        }
    }
    out
}

/// Numerically-stable softmax over the last axis of a 2-D tensor, in place.
pub fn softmax_rows(x: &mut Tensor) {
    let c = x.cols();
    for i in 0..x.rows() {
        softmax_inplace(&mut x.data_mut()[i * c..(i + 1) * c]);
    }
}

/// Stable softmax of a single vector in place.
pub fn softmax_inplace(v: &mut [f32]) {
    let max = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in v.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in v.iter_mut() {
        *x *= inv;
    }
}

/// LayerNorm over the last axis: (x - mean) / sqrt(var + eps) * gamma + beta.
pub fn layernorm(x: &[f32], gamma: &[f32], beta: &[f32], eps: f32, out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + eps).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * gamma[i] + beta[i];
    }
}

/// GELU activation (tanh approximation, matching jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Frobenius norm of a slice.
pub fn fro_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// || a - b ||_F
pub fn fro_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]);
        assert_eq!(matmul(&a, &b).data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(2);
        let a = Tensor::randn(&[5, 7], &mut r, 1.0);
        let mut eye = Tensor::zeros(&[7, 7]);
        for i in 0..7 {
            eye.data_mut()[i * 7 + i] = 1.0;
        }
        let c = matmul(&a, &eye);
        assert_eq!(c.data(), a.data());
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        let mut r = Rng::new(3);
        let a = Tensor::randn(&[4, 6], &mut r, 1.0);
        let b = Tensor::randn(&[5, 6], &mut r, 1.0);
        let mut c1 = vec![0.0; 4 * 5];
        matmul_bt_into(a.data(), b.data(), 4, 6, 5, &mut c1);
        let c2 = matmul(&a, &b.t());
        for (x, y) in c1.iter().zip(c2.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(4);
        let a = Tensor::randn(&[3, 8], &mut r, 1.0);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut t = Tensor::new(&[2, 3], vec![1., 2., 3., 1000., 1000., 1000.]);
        softmax_rows(&mut t);
        for i in 0..2 {
            let s: f32 = t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // large-input row must not produce NaN
        assert!(t.row(1).iter().all(|x| x.is_finite()));
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let gamma = vec![1.0f32; 4];
        let beta = vec![0.0f32; 4];
        let mut out = vec![0.0f32; 4];
        layernorm(&x, &gamma, &beta, 1e-5, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_known_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(100.0) - 100.0).abs() < 1e-3);
        assert!(gelu(-100.0).abs() < 1e-3);
    }

    #[test]
    fn fro_dist_triangle() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!((fro_dist(&a, &b) - (2.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut r = Rng::new(9);
        for n in [1usize, 3, 4, 7, 16, 33] {
            let a: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4);
        }
    }
}
