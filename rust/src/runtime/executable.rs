//! HLO executable loading + execution on the PJRT CPU client.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`) — see
//! /opt/xla-example/README.md for why serialized protos from jax ≥ 0.5 are
//! rejected by xla_extension 0.5.1. All graphs were lowered with
//! `return_tuple=True`, so outputs decompose into tuples.

use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{Context, Result};

use crate::tensor::Tensor;

impl From<xla::Error> for crate::util::error::Error {
    fn from(e: xla::Error) -> Self {
        crate::util::error::Error::msg(e)
    }
}

/// PJRT client + a cache of compiled executables keyed by artifact name.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text file under `name` (idempotent).
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute `name` with the given argument literals; returns the
    /// flattened output tuple.
    pub fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("executable {name} not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

// --- literal <-> tensor bridge ------------------------------------------------

/// f32 tensor -> literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Raw f32 slice + shape -> literal.
pub fn slice_to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// i32 data + shape -> literal.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// literal -> f32 vec (flattened).
pub fn literal_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    //! Requires a built artifacts directory; each test skips (with a note)
    //! when `make artifacts` hasn't run. Full validation lives in
    //! `tests/xla_integration.rs`.
    use super::*;
    use crate::runtime::artifacts::Artifacts;

    #[test]
    fn literal_round_trip() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_vec(&l).unwrap(), t.data());
    }

    #[test]
    fn load_and_run_prefill_if_available() {
        if !Artifacts::available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // Weights travel as runtime arguments; XlaModel assembles them from
        // the manifest's param_order.
        let xm = crate::runtime::xla_model::XlaModel::load_default().unwrap();
        let prompt = vec![1u32; 16];
        let (logits, st) = xm.prefill(&prompt, 128).unwrap();
        assert_eq!(logits.len(), xm.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(st.len, 16);
    }
}
