//! Generation through the AOT-compiled XLA graphs (`--backend xla`).
//!
//! Holds a dense FP16-accounted KV cache in Rust and drives the bucketed
//! `prefill_{n}` / `decode_{n}` executables. Used to (a) prove the
//! three-layer architecture end-to-end (JAX-authored, AOT-lowered,
//! Rust-executed, no Python at serve time) and (b) cross-validate the pure
//! Rust forward (`tests/xla_integration.rs` compares logits).

use std::path::Path;

use crate::util::error::{bail, Context, Result};

use crate::runtime::artifacts::Artifacts;
use crate::runtime::executable::{
    i32_literal, i32_scalar, literal_to_vec, slice_to_literal, XlaRuntime,
};

/// Model served via XLA executables.
pub struct XlaModel {
    rt: XlaRuntime,
    art: Artifacts,
    /// Weight literals in the manifest's `param_order` (weights travel as
    /// runtime arguments — the HLO text printer elides large constants, so
    /// baking them would corrupt the graph).
    params: Vec<xla::Literal>,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
}

/// Per-request dense KV state for the XLA path, padded to a decode bucket.
pub struct XlaKvState {
    bucket: usize,
    /// [L, bucket, d] row-major.
    k: Vec<f32>,
    v: Vec<f32>,
    pub len: usize,
}

impl XlaModel {
    /// Load all bucketed executables from the default artifacts dir.
    pub fn load_default() -> Result<XlaModel> {
        Self::load(&Artifacts::default_dir())
    }

    pub fn load(dir: &Path) -> Result<XlaModel> {
        let art = Artifacts::load(dir)?;
        let mut rt = XlaRuntime::cpu()?;
        for n in art.buckets("prefill_") {
            rt.load(&format!("prefill_{n}"), &art.path(&format!("prefill_{n}"))?)?;
        }
        for n in art.buckets("decode_") {
            rt.load(&format!("decode_{n}"), &art.path(&format!("decode_{n}"))?)?;
        }
        // Weight literals, ordered per the manifest.
        let bytes = std::fs::read(art.path("weights")?).context("reading weights.bin")?;
        let tensors = crate::model::weights::read_tensor_map(&bytes)?;
        let order = art
            .get("param_order")
            .context("manifest missing param_order (re-run `make artifacts`)")?;
        let mut params = Vec::new();
        for name in order.split(',') {
            let t = tensors
                .get(name)
                .with_context(|| format!("weights.bin missing tensor {name}"))?;
            params.push(crate::runtime::executable::tensor_to_literal(t)?);
        }
        Ok(XlaModel {
            vocab: art.get_usize("vocab")?,
            d_model: art.get_usize("d_model")?,
            n_layers: art.get_usize("n_layers")?,
            params,
            rt,
            art,
        })
    }

    /// Prefill: pads the prompt into the smallest prefill bucket.
    ///
    /// The prefill graphs run full (unmasked-length) attention over the
    /// bucket, so padding would perturb logits; instead we require an exact
    /// bucket match or pad with PAD tokens *after* the prompt and read K/V
    /// rows only up to the true length — the returned last-position logits
    /// come from re-running decode on the final token when padding was
    /// needed. For simplicity and exactness, prompts are right-padded to
    /// the bucket and the *cache* keeps only true rows; last logits are
    /// recomputed via one decode step when `prompt.len() != bucket`.
    pub fn prefill(&self, prompt: &[u32], decode_bucket: usize) -> Result<(Vec<f32>, XlaKvState)> {
        let Some(pb) = self.art.pick_bucket("prefill_", prompt.len()) else {
            bail!("prompt length {} exceeds all prefill buckets", prompt.len());
        };
        if !self.art.buckets("decode_").contains(&decode_bucket) {
            bail!("no decode bucket {decode_bucket}");
        }
        // Causal attention: padding AFTER the prompt cannot influence
        // positions <= prompt end, so K/V rows [0, len) are exact.
        let mut ids: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        let true_len = ids.len();
        ids.resize(pb, 0); // PAD id 0
        let mut args: Vec<xla::Literal> = self.clone_params();
        args.push(i32_literal(&ids, &[1, pb])?);
        let out = self
            .rt
            .run(&format!("prefill_{pb}"), &args)
            .context("prefill execution")?;
        let k_full = literal_to_vec(&out[1])?;
        let v_full = literal_to_vec(&out[2])?;

        let (l, d) = (self.n_layers, self.d_model);
        let mut st = XlaKvState {
            bucket: decode_bucket,
            k: vec![0.0; l * decode_bucket * d],
            v: vec![0.0; l * decode_bucket * d],
            len: true_len,
        };
        for li in 0..l {
            let src = li * pb * d;
            let dst = li * decode_bucket * d;
            st.k[dst..dst + true_len * d]
                .copy_from_slice(&k_full[src..src + true_len * d]);
            st.v[dst..dst + true_len * d]
                .copy_from_slice(&v_full[src..src + true_len * d]);
        }

        let logits = if true_len == pb {
            literal_to_vec(&out[0])?
        } else {
            // Recompute exact last-position logits: pop the final token and
            // run it as a decode step against the first true_len-1 rows.
            st.len = true_len - 1;
            let logits = self.decode(*prompt.last().unwrap(), true_len - 1, &mut st)?;
            debug_assert_eq!(st.len, true_len);
            logits
        };
        Ok((logits, st))
    }

    fn clone_params(&self) -> Vec<xla::Literal> {
        self.params.clone()
    }

    /// One decode step: appends the token's K/V into the state and returns
    /// logits.
    pub fn decode(&self, token: u32, pos: usize, st: &mut XlaKvState) -> Result<Vec<f32>> {
        let (l, d, n) = (self.n_layers, self.d_model, st.bucket);
        if st.len >= n {
            bail!("decode bucket {n} exhausted");
        }
        let mut args: Vec<xla::Literal> = self.clone_params();
        args.push(i32_scalar(token as i32));
        args.push(i32_scalar(pos as i32));
        args.push(slice_to_literal(&st.k, &[l, n, d])?);
        args.push(slice_to_literal(&st.v, &[l, n, d])?);
        args.push(i32_scalar(st.len as i32));
        let out = self.rt.run(&format!("decode_{n}"), &args)?;
        let logits = literal_to_vec(&out[0])?;
        let k_new = literal_to_vec(&out[1])?;
        let v_new = literal_to_vec(&out[2])?;
        for li in 0..l {
            let dst = li * n * d + st.len * d;
            st.k[dst..dst + d].copy_from_slice(&k_new[li * d..(li + 1) * d]);
            st.v[dst..dst + d].copy_from_slice(&v_new[li * d..(li + 1) * d]);
        }
        st.len += 1;
        Ok(logits)
    }

    /// Greedy generation; stops on any stop token or `max_new` tokens.
    pub fn generate_greedy(
        &self,
        prompt: &[u32],
        max_new: usize,
        stop: &[u32],
    ) -> Result<Vec<u32>> {
        let bucket = self
            .art
            .pick_bucket("decode_", prompt.len() + max_new + 1)
            .context("no decode bucket large enough")?;
        let (mut logits, mut st) = self.prefill(prompt, bucket)?;
        let mut out = Vec::new();
        for _ in 0..max_new {
            let next = crate::model::sampler::argmax(&logits);
            if stop.contains(&next) {
                break;
            }
            out.push(next);
            logits = self.decode(next, st.len, &mut st)?;
        }
        Ok(out)
    }
}
