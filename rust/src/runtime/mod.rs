//! PJRT (XLA) runtime: loads the AOT-compiled JAX graphs from `artifacts/`
//! and executes them on the request path — Python is never invoked at serve
//! time.
//!
//! * [`artifacts`] — manifest parsing + artifact discovery.
//! * [`executable`] — HLO-text loading, compilation, literal⇄tensor bridge.
//! * [`xla_model`] — generation loop over the bucketed prefill/decode
//!   executables with a dense KV cache (the `--backend xla` path), plus the
//!   fused GEAR-attention executable (the Pallas L1 kernel, AOT-lowered).

pub mod artifacts;
pub mod executable;
pub mod xla_model;

pub use artifacts::Artifacts;
pub use executable::XlaRuntime;
