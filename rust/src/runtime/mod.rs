//! PJRT (XLA) runtime: loads the AOT-compiled JAX graphs from `artifacts/`
//! and executes them on the request path — Python is never invoked at serve
//! time.
//!
//! * [`artifacts`] — manifest parsing + artifact discovery.
//! * [`executable`] — HLO-text loading, compilation, literal⇄tensor bridge.
//! * [`xla_model`] — generation loop over the bucketed prefill/decode
//!   executables with a dense KV cache (the `--backend xla` path), plus the
//!   fused GEAR-attention executable (the Pallas L1 kernel, AOT-lowered).

//! The PJRT-backed modules are gated behind the `xla` cargo feature: they
//! need the vendored `xla` crate and the xla_extension shared library,
//! neither of which exists on a plain offline build host. [`artifacts`] is
//! pure Rust and always available.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod executable;
#[cfg(feature = "xla")]
pub mod xla_model;

pub use artifacts::Artifacts;
#[cfg(feature = "xla")]
pub use executable::XlaRuntime;
