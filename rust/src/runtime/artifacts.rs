//! Artifact discovery and manifest parsing.
//!
//! `make artifacts` produces `artifacts/manifest.txt` as newline-delimited
//! `key=value` pairs (see `python/compile/aot.py`). This module locates the
//! directory (`GEAR_ARTIFACTS` env var, else `./artifacts`) and indexes it.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};

/// Parsed artifacts directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    entries: HashMap<String, String>,
}

impl Artifacts {
    /// Default location: `$GEAR_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("GEAR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// True if a built artifacts directory is present (used by tests to
    /// skip gracefully when `make artifacts` hasn't run).
    pub fn available() -> bool {
        Self::default_dir().join("manifest.txt").exists()
    }

    pub fn load_default() -> Result<Artifacts> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest.display()))?;
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("malformed manifest line: {line}");
            };
            entries.insert(k.to_string(), v.to_string());
        }
        Ok(Artifacts { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .with_context(|| format!("manifest missing {key}"))?
            .parse()
            .with_context(|| format!("manifest {key} not an integer"))
    }

    /// Absolute path of a manifest-referenced file.
    pub fn path(&self, key: &str) -> Result<PathBuf> {
        let rel = self.get(key).with_context(|| format!("manifest missing {key}"))?;
        let p = self.dir.join(rel);
        if !p.exists() {
            bail!("artifact {key} -> {} does not exist", p.display());
        }
        Ok(p)
    }

    /// All bucket sizes present for a prefix like `prefill_` / `decode_`.
    pub fn buckets(&self, prefix: &str) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .entries
            .keys()
            .filter_map(|k| k.strip_prefix(prefix).and_then(|s| s.parse().ok()))
            .collect();
        out.sort_unstable();
        out
    }

    /// Smallest bucket >= n.
    pub fn pick_bucket(&self, prefix: &str, n: usize) -> Option<usize> {
        self.buckets(prefix).into_iter().find(|&b| b >= n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, text: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        f.write_all(text.as_bytes()).unwrap();
    }

    #[test]
    fn parses_and_indexes() {
        let td = std::env::temp_dir().join(format!("gear_art_{}", std::process::id()));
        std::fs::create_dir_all(&td).unwrap();
        write_manifest(
            &td,
            "d_model=128\nprefill_64=prefill_64.hlo.txt\nprefill_128=prefill_128.hlo.txt\n",
        );
        let a = Artifacts::load(&td).unwrap();
        assert_eq!(a.get_usize("d_model").unwrap(), 128);
        assert_eq!(a.buckets("prefill_"), vec![64, 128]);
        assert_eq!(a.pick_bucket("prefill_", 65), Some(128));
        assert_eq!(a.pick_bucket("prefill_", 300), None);
        assert!(a.path("prefill_64").is_err()); // file absent
        std::fs::remove_dir_all(&td).ok();
    }

    #[test]
    fn rejects_malformed() {
        let td = std::env::temp_dir().join(format!("gear_art_bad_{}", std::process::id()));
        std::fs::create_dir_all(&td).unwrap();
        write_manifest(&td, "no-equals-sign\n");
        assert!(Artifacts::load(&td).is_err());
        std::fs::remove_dir_all(&td).ok();
    }
}
