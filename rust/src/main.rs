//! gear-serve CLI: the layer-3 leader entrypoint.
//!
//! ```text
//! gear-serve info                                   artifact + model summary
//! gear-serve serve  [--addr A] [--spec S] [--budget-mb N] [--max-new N] [--trace PATH]
//! gear-serve eval   [--task hard|easy] [--spec S] [--n N] [--backend rust|xla]
//! gear-serve demo   [--spec S]                      one-shot generation demo
//! ```
//!
//! Spec strings: fp16, gear-2, gear-4, gear-l-2, gear-l-4, kivi-2, kivi-4,
//! kcvt-4, per-token-4, h2o-50.

use gear_serve::util::error::{bail, Context, Result};

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::GenRequest;
use gear_serve::coordinator::server;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::Tokenizer;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::workload::tasks::{self, Task};

/// Tiny flag parser: --key value pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {}", argv[i]))?;
            let v = argv.get(i + 1).with_context(|| format!("--{k} needs a value"))?;
            flags.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }
}

fn load_model() -> Result<Model> {
    let path = Artifacts::default_dir().join("weights.bin");
    let weights = ModelWeights::load(&path)
        .with_context(|| format!("loading {} (run `make artifacts`)", path.display()))?;
    Ok(Model::new(weights))
}

fn parse_spec(s: &str) -> Result<CacheSpec> {
    CacheSpec::parse(s).with_context(|| format!("unknown cache spec {s:?}"))
}

fn cmd_info() -> Result<()> {
    if !Artifacts::available() {
        bail!("artifacts not built — run `make artifacts`");
    }
    let art = Artifacts::load_default()?;
    println!("artifacts dir : {}", art.dir.display());
    for key in ["vocab", "d_model", "n_layers", "n_heads", "max_seq"] {
        println!("{key:<14}: {}", art.get(key).unwrap_or("?"));
    }
    println!("prefill buckets: {:?}", art.buckets("prefill_"));
    println!("decode buckets : {:?}", art.buckets("decode_"));
    let model = load_model()?;
    let n_params: usize = {
        let w = &model.weights;
        let mut n = w.emb.len() + w.pos.len() + w.head.len() + w.lnf_g.len() + w.lnf_b.len();
        for b in &w.blocks {
            n += b.wq.len() + b.wk.len() + b.wv.len() + b.wo.len();
            n += b.w1.len() + b.w2.len() + b.b1.len() + b.b2.len();
            n += b.ln1_g.len() + b.ln1_b.len() + b.ln2_g.len() + b.ln2_b.len();
        }
        n
    };
    println!("parameters    : {n_params}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let spec = parse_spec(&args.get("spec", "gear-2"))?;
    let addr = args.get("addr", "127.0.0.1:7777");
    let budget_mb = args.get_usize("budget-mb", 0)?;
    let max_new = args.get_usize("max-new", 64)?;
    let model = load_model()?;
    let mut cfg = EngineConfig::new(spec);
    if budget_mb > 0 {
        cfg = cfg.with_budget(budget_mb << 20);
    }
    // --trace PATH writes Perfetto JSON to PATH and the JSONL journal next
    // to it; the GEAR_TRACE env var is the config-free equivalent.
    let trace = args.get("trace", "");
    if !trace.is_empty() {
        cfg = cfg.with_trace(&trace);
        println!("trace: {trace} (+ .jsonl journal)");
    }
    println!("spec: {} | budget: {} | addr: {addr}", spec.label(),
             if budget_mb > 0 { format!("{budget_mb} MiB") } else { "unlimited".into() });
    let client = server::spawn_engine(model, cfg);
    server::serve(&addr, client, max_new)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let spec = parse_spec(&args.get("spec", "gear-2"))?;
    let n = args.get_usize("n", 50)?;
    let task = match args.get("task", "hard").as_str() {
        "hard" => Task::hard(),
        "easy" => Task::easy(),
        other => bail!("unknown task {other} (hard|easy)"),
    };
    let backend = args.get("backend", "rust");
    let tok = Tokenizer::new();
    let set = tasks::generate_set(task, n, 42);

    let (mut correct, mut total_gen) = (0usize, 0usize);
    match backend.as_str() {
        "rust" => {
            let model = load_model()?;
            let mut engine = Engine::new(model, EngineConfig::new(spec));
            for (i, inst) in set.iter().enumerate() {
                engine.submit(
                    GenRequest::greedy(i as u64, tok.encode_with_bos(&inst.prompt), 64)
                        .with_newline_stop(),
                );
            }
            let mut results = engine.run_to_completion();
            results.sort_by_key(|r| r.id);
            for (r, inst) in results.iter().zip(&set) {
                total_gen += r.output.len();
                correct += tasks::score(&r.text(), inst) as usize;
            }
            println!(
                "throughput: {:.1} tok/s | peak cache: {:.2} MiB",
                engine.metrics.throughput(),
                engine.metrics.peak_cache_bytes as f64 / (1 << 20) as f64
            );
        }
        #[cfg(feature = "xla")]
        "xla" => {
            let xm = gear_serve::runtime::xla_model::XlaModel::load_default()?;
            let nl = tok.encode("\n")[0];
            for inst in &set {
                let out = xm.generate_greedy(
                    &tok.encode_with_bos(&inst.prompt),
                    64,
                    &[gear_serve::model::config::EOS, nl],
                )?;
                total_gen += out.len();
                correct += tasks::score(&tok.decode(&out), inst) as usize;
            }
            println!("(xla backend serves FP16 dense cache; compression evals use --backend rust)");
        }
        #[cfg(not(feature = "xla"))]
        "xla" => bail!("xla backend requires building with --features xla"),
        other => bail!("unknown backend {other} (rust|xla)"),
    }
    println!(
        "task {} | spec {} | accuracy {}/{} = {:.1}% | avg gen len {:.1}",
        task.label(),
        spec.label(),
        correct,
        n,
        100.0 * correct as f64 / n as f64,
        total_gen as f64 / n as f64,
    );
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let spec = parse_spec(&args.get("spec", "gear-2"))?;
    let model = load_model()?;
    let tok = Tokenizer::new();
    let inst = tasks::generate_set(Task::hard(), 1, 7).remove(0);
    println!("prompt:\n{}", inst.prompt);
    let mut engine = Engine::new(model, EngineConfig::new(spec));
    engine.submit(GenRequest::greedy(0, tok.encode_with_bos(&inst.prompt), 64).with_newline_stop());
    let r = engine.run_to_completion().remove(0);
    println!("generated: {}", r.text());
    println!("expected : {}", inst.completion.trim_end());
    println!("correct  : {}", tasks::score(&r.text(), &inst));
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            eprintln!("usage: gear-serve <info|serve|eval|demo> [--flags]");
            std::process::exit(2);
        }
    };
    let args = Args::parse(rest)?;
    match cmd {
        "info" => cmd_info(),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "demo" => cmd_demo(&args),
        other => bail!("unknown command {other}"),
    }
}
