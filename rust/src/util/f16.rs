//! IEEE 754 binary16 conversion.
//!
//! The compute path in this crate is f32 (the PJRT CPU client and the tiny
//! model both run f32), but the paper's memory accounting is in FP16. Cache
//! components that the paper stores in FP16 (scales, zero-points, outlier
//! values, low-rank factors, streaming buffer) are *stored* here as packed
//! `u16` half floats so the byte accounting is real, not simulated.

/// Convert f32 -> f16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;

    if exp == 0xff {
        // Inf / NaN.
        let m = if mant != 0 { 0x200 } else { 0 };
        return sign | 0x7c00 | m;
    }
    // Re-bias exponent: f32 bias 127 -> f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal range. Round mantissa from 23 to 10 bits.
        let mant16 = mant >> 13;
        let rest = mant & 0x1fff;
        let half = 0x1000;
        let mut out = sign | (((unbiased + 15) as u16) << 10) | mant16 as u16;
        if rest > half || (rest == half && (mant16 & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent: still correct
        }
        return out;
    }
    if unbiased >= -24 {
        // Subnormal f16: value = mant16 * 2^-24 with mant16 = full * 2^(unbiased+1)
        // for the 24-bit significand `full`.
        let shift = (-unbiased - 1) as u32; // in 14..=23
        let full = mant | 0x80_0000;
        let mant16 = full >> shift;
        let rest = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut out = sign | mant16 as u16;
        if rest > half || (rest == half && (mant16 & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    sign // underflow -> signed zero
}

/// Convert f16 bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: value = mant * 2^-24. Normalize: with the top set
            // bit of mant at position p, exponent = p - 24 + 127 = 103 + p.
            let shift = mant.leading_zeros() - 21; // = 10 - p
            let e = 113 - shift;
            let m = (mant << (13 + shift)) & 0x7f_ffff;
            sign | (e << 23) | m
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an f32 through f16 precision (what storing in FP16 costs).
pub fn to_f16_precision(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// A compact FP16 buffer: stores values as packed u16, two bytes each.
#[derive(Debug, Clone, Default)]
pub struct F16Buf {
    bits: Vec<u16>,
}

impl F16Buf {
    pub fn from_f32(xs: &[f32]) -> Self {
        F16Buf { bits: xs.iter().map(|&x| f32_to_f16_bits(x)).collect() }
    }

    pub fn with_capacity(n: usize) -> Self {
        F16Buf { bits: Vec::with_capacity(n) }
    }

    pub fn push(&mut self, x: f32) {
        self.bits.push(f32_to_f16_bits(x));
    }

    pub fn get(&self, i: usize) -> f32 {
        f16_bits_to_f32(self.bits[i])
    }

    pub fn len(&self) -> usize {
        self.bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.bits.iter().map(|&b| f16_bits_to_f32(b)).collect()
    }

    /// Actual storage bytes.
    pub fn nbytes(&self) -> usize {
        self.bits.len() * 2
    }

    pub fn clear(&mut self) {
        self.bits.clear();
    }

    pub fn extend_from_f32(&mut self, xs: &[f32]) {
        self.bits.extend(xs.iter().map(|&x| f32_to_f16_bits(x)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 2.0, 0.5, 65504.0, -65504.0] {
            assert_eq!(to_f16_precision(x), x, "{x} should be exactly representable");
        }
    }

    #[test]
    fn special_values() {
        assert!(to_f16_precision(f32::INFINITY).is_infinite());
        assert!(to_f16_precision(f32::NEG_INFINITY).is_infinite());
        assert!(to_f16_precision(f32::NAN).is_nan());
        assert_eq!(to_f16_precision(1e9), f32::INFINITY); // overflow
        assert_eq!(to_f16_precision(1e-30), 0.0); // underflow
    }

    #[test]
    fn relative_error_bound() {
        let mut r = crate::util::rng::Rng::new(5);
        for _ in 0..10_000 {
            let x = (r.normal_f32()) * 100.0;
            let y = to_f16_precision(x);
            let rel = ((y - x) / x.abs().max(1e-6)).abs();
            // f16 has 10 mantissa bits -> rel err <= 2^-11 for normals.
            assert!(rel <= 4.9e-4, "x={x} y={y} rel={rel}");
        }
    }

    #[test]
    fn subnormals_roundtrip() {
        // Smallest positive subnormal f16 = 2^-24.
        let tiny = 2.0_f32.powi(-24);
        assert_eq!(to_f16_precision(tiny), tiny);
        let sub = 2.0_f32.powi(-20);
        assert_eq!(to_f16_precision(sub), sub);
    }

    #[test]
    fn buf_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, 100.0];
        let b = F16Buf::from_f32(&xs);
        assert_eq!(b.nbytes(), 8);
        assert_eq!(b.to_f32_vec(), xs);
    }
}
