//! Plain-text table rendering for the bench harness.
//!
//! Every paper table/figure reproduction prints through this so outputs are
//! uniform and easy to diff across runs.

/// A simple left-padded text table.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncols {
                line.push_str(&format!("{:<w$} | ", cells[i], w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let sep: String = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('|');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Format a fraction as a percentage string like "23.6%".
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format a float with 2..4 significant-ish digits for table cells.
pub fn sig(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["method", "acc"]);
        t.row(vec!["FP16".into(), "54.21".into()]);
        t.row(vec!["GEAR-long-name".into(), "54.0".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| method"));
        assert!(s.lines().count() >= 5);
        // All data lines equal width.
        let lens: Vec<usize> =
            s.lines().skip(1).filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        // header and rows may differ by trailing trim; check within 1 char
        let max = *lens.iter().max().unwrap();
        let min = *lens.iter().min().unwrap();
        assert!(max - min <= 1, "{lens:?}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(0.236), "23.6%");
        assert_eq!(sig(0.0), "0");
        assert_eq!(sig(123.4), "123");
        assert_eq!(sig(3.14159), "3.14");
        assert_eq!(sig(0.01234), "0.0123");
    }
}
