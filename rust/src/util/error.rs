//! Minimal error handling for the offline build.
//!
//! The vendor set has no `anyhow`, so this module provides the small subset
//! the crate needs: a string-backed [`Error`], a [`Result`] alias, a
//! [`Context`] extension trait for `Result`/`Option`, and a [`bail!`] macro.
//! Context messages are prepended (`"context: cause"`), so `to_string()`
//! contains the full chain — what the error-path tests assert on.

use std::fmt;

/// String-backed error with prepended context.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }

    fn wrap(context: impl fmt::Display, cause: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {cause}") }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-shaped extension for attaching context to failures.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::wrap(context, e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::wrap(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

pub use crate::bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad magic {:?}", [1u8, 2])
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert!(e.to_string().contains("bad magic"));
        assert!(format!("{e:?}").contains("bad magic"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::other("boom"));
        let e = r.context("reading header").unwrap_err();
        assert!(e.to_string().contains("reading header"));
        assert!(e.to_string().contains("boom"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing tensor {}", "emb")).unwrap_err();
        assert!(e.to_string().contains("missing tensor emb"));
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }
}
