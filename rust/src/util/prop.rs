//! Minimal in-repo property-based testing framework.
//!
//! The offline vendor set has no `proptest`, so this module provides the
//! subset we need: seeded generators, a `forall` runner with failure
//! reporting (seed + case index, so any failure is reproducible), and a
//! simple halving shrinker for numeric/size parameters.

use crate::util::rng::Rng;

/// Number of cases each property runs by default.
pub const DEFAULT_CASES: usize = 64;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: DEFAULT_CASES, seed: 0x6EA7_5EED }
    }
}

/// Run `prop` on `cases` generated inputs. `gen` receives an independent RNG
/// per case. On failure, panics with the case index and seed so the exact
/// case can be replayed.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut r = root.split();
        let input = gen(&mut r);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}):\n  input: {:?}\n  {msg}",
                cfg.seed, input
            );
        }
    }
}

/// Convenience: run with the default config.
pub fn check<T: std::fmt::Debug>(
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall(Config::default(), gen, prop)
}

/// Generate a random matrix shape (rows, cols) within bounds.
pub fn gen_shape(r: &mut Rng, max_rows: usize, max_cols: usize) -> (usize, usize) {
    (1 + r.next_below(max_rows as u64) as usize, 1 + r.next_below(max_cols as u64) as usize)
}

/// Generate a random f32 vector with mixed scales (normals + occasional
/// outliers), the regime KV caches live in.
pub fn gen_kv_like(r: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    for x in v.iter_mut() {
        *x = r.normal_f32();
        if r.next_f64() < 0.02 {
            *x *= 20.0; // outlier
        }
    }
    v
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        check(|r| r.next_below(100), |&x| if x < 100 { Ok(()) } else { Err("oob".into()) });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        check(|r| r.next_below(10), |&x| if x < 5 { Ok(()) } else { Err(format!("x={x}")) });
    }

    #[test]
    fn shapes_in_bounds() {
        check(
            |r| gen_shape(r, 33, 65),
            |&(rows, cols)| {
                if (1..=33).contains(&rows) && (1..=65).contains(&cols) {
                    Ok(())
                } else {
                    Err(format!("shape {rows}x{cols}"))
                }
            },
        );
    }
}
