//! Small self-contained utilities shared across the crate.
//!
//! The build is fully offline against a fixed vendor set, so facilities that
//! would normally come from external crates (property testing, f16
//! conversion, table formatting, error context) are implemented here.

pub mod error;
pub mod f16;
pub mod prop;
pub mod rng;
pub mod table;
pub mod timing;
