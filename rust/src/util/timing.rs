//! Timing helpers for the bench harness and the engine's time-breakdown
//! metrics (Fig 3a reproduction).

use std::time::{Duration, Instant};

/// Measure wall time of `f`, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Run `f` `iters` times after `warmup` warmup runs; returns per-iteration
/// stats in nanoseconds.
pub fn bench_loop<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    Stats::from_samples(&mut samples)
}

/// Simple summary statistics over nanosecond samples.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean_ns: f64,
    pub median_ns: u64,
    pub p95_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl Stats {
    pub fn from_samples(samples: &mut [u64]) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let sum: u128 = samples.iter().map(|&x| x as u128).sum();
        Stats {
            n,
            mean_ns: sum as f64 / n as f64,
            median_ns: samples[n / 2],
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
            min_ns: samples[0],
            max_ns: samples[n - 1],
        }
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Accumulates wall time per named phase; the engine uses one of these to
/// produce the paper's Fig 3a wall-clock breakdown.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &str, d: Duration) {
        if let Some(p) = self.phases.iter_mut().find(|(name, _)| name == phase) {
            p.1 += d;
        } else {
            self.phases.push((phase.to_string(), d));
        }
    }

    /// Time `f`, attributing the elapsed time to `phase`.
    pub fn scope<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let (out, d) = timed(f);
        self.add(phase, d);
        out
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }

    pub fn get(&self, phase: &str) -> Duration {
        self.phases
            .iter()
            .find(|(name, _)| name == phase)
            .map(|(_, d)| *d)
            .unwrap_or_default()
    }

    /// (phase, seconds, fraction-of-total) rows.
    pub fn breakdown(&self) -> Vec<(String, f64, f64)> {
        let total = self.total().as_secs_f64().max(1e-12);
        self.phases
            .iter()
            .map(|(name, d)| (name.clone(), d.as_secs_f64(), d.as_secs_f64() / total))
            .collect()
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (name, d) in &other.phases {
            self.add(name, *d);
        }
    }

    pub fn clear(&mut self) {
        self.phases.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = vec![10, 20, 30, 40, 50];
        let st = Stats::from_samples(&mut s);
        assert_eq!(st.n, 5);
        assert_eq!(st.min_ns, 10);
        assert_eq!(st.max_ns, 50);
        assert_eq!(st.median_ns, 30);
        assert!((st.mean_ns - 30.0).abs() < 1e-9);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("quant", Duration::from_millis(10));
        t.add("quant", Duration::from_millis(5));
        t.add("lowrank", Duration::from_millis(5));
        assert_eq!(t.get("quant"), Duration::from_millis(15));
        assert_eq!(t.total(), Duration::from_millis(20));
        let rows = t.breakdown();
        assert_eq!(rows.len(), 2);
        assert!((rows[0].2 - 0.75).abs() < 1e-9);
    }

    #[test]
    fn scope_attributes_time() {
        let mut t = PhaseTimer::new();
        let x = t.scope("work", || 2 + 2);
        assert_eq!(x, 4);
        assert!(t.get("work") > Duration::ZERO);
    }
}
