//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 core with Box–Muller normal sampling. Deterministic seeding is
//! load-bearing: the Python build path (`python/compile/`) and the Rust
//! request path must generate identical synthetic workloads for the golden
//! cross-language tests, so we avoid platform RNGs entirely.

/// SplitMix64 generator (Steele et al., "Fast splittable pseudorandom number
/// generators"). Passes BigCrush for our purposes and is trivially portable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of the last Box–Muller transform.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, spare_normal: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(mu, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        for x in out.iter_mut() {
            *x = mu + sigma * self.normal_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }

    /// Derive an independent child generator (split).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = r.next_below(7) as usize;
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
