//! Compressed per-layer KV cache with the paper's streaming-buffer strategy
//! (§3 "Streaming Buffer", Algorithm 1).
//!
//! Layout per layer: a list of immutable compressed *segments* plus a small
//! FP16 ring of recent tokens (the buffer `B`, capacity `n_b`). Prefill
//! compresses the whole prompt at rank `r_p`; during decoding, every `n_b`
//! appended tokens are compressed as one chunk at rank `r_g` (the paper uses
//! r_p = 4, r_g = 2). Attention runs fused against every segment (see
//! `gear::attend`) and dense against the buffer.
//!
//! ## Flush cadences and the determinism contract
//!
//! Three flush cadences share one compression path, and all three produce
//! identical segments from identical rows (the compression is a pure
//! function of the rows, the method, and a seed derived from both):
//!
//! * **Inline** — [`LayerKv::append`] compresses the moment the buffer
//!   fills (standalone decode loops, tests, analysis tools).
//! * **Deferred-synchronous** — [`LayerKv::append_deferred`] only *seals*
//!   the full buffer; [`LayerKv::run_flush`] compresses it later on the
//!   calling thread. A seal left behind by a caller that never flushes
//!   self-heals at the next append.
//! * **Detached** (the engine's cadence) — [`LayerKv::detach_flush`] hands
//!   the sealed rows out as an owned [`super::FlushWork`] snapshot and
//!   marks them `in_flight`. The rows *stay in the buffer*: `len()`,
//!   `nbytes()`, and attention keep observing them as dense FP16 rows, so
//!   nothing the next sweep reads depends on when the job actually runs.
//!   [`LayerKv::install_flush`] later swaps the rows for the compressed
//!   segments at the engine's commit point — the single place byte
//!   accounting observes the cache — and [`LayerKv::step_growth_bound`]
//!   accounts for that pending install (plus any still-pending seal), so
//!   the engine's reservations cover the swap before it happens.
//!
//! While a detached job is in flight the layer refuses inline flushes
//! (segments are oldest-first; compressing newer rows before the in-flight
//! ones land would corrupt that order). The engine upholds the protocol by
//! joining a request's outstanding jobs at its next commit *before*
//! detaching new seals, so at most one job per layer is ever in flight.
//!
//! *Who* runs a detached job is invisible to this module: the work is a
//! pure function of the snapshot, so any worker may service it. Under the
//! engine's pipelined plane each flush is tagged with its layer index and
//! preferentially drained by the pipeline stage that owns that layer —
//! pure locality routing; the install/commit protocol above is unchanged.
//!
//! **Tracing.** This module emits nothing itself. The engine records the
//! `seal`/`flush_submit` pair at the detach point and `flush_join` at the
//! install/commit point (see [`crate::trace`]); the compression call
//! inside the worker stages per-matrix GEAR quality probes
//! (achieved-vs-predicted bytes, Eq. (4) residual norms) that ride the
//! flush observation back to those same deterministic commit points.

use crate::gear::compose::{compress, CompressedMatrix, GearConfig, Method};
use crate::gear::size::SizeBreakdown;
use crate::gear::KvKind;
use crate::tensor::ops::dot;
use crate::tensor::Tensor;
use crate::util::f16::to_f16_precision;

use super::dense::softmax_heads;
use super::{AttendScratch, FlushResult, FlushWork, LayerKv};

pub struct GearLayerKv {
    d: usize,
    n_heads: usize,
    method: Method,
    buffer_cap: usize,
    prefill_rank: usize,
    decode_rank: usize,
    /// Compressed segments, oldest first. K and V stay index-aligned.
    seg_k: Vec<CompressedMatrix>,
    seg_v: Vec<CompressedMatrix>,
    /// FP16-rounded buffer rows (row-major, up to buffer_cap × d).
    buf_k: Vec<f32>,
    buf_v: Vec<f32>,
    buf_n: usize,
    /// Total tokens across segments (excluding buffer).
    seg_tokens: usize,
    /// Buffer reached capacity under [`LayerKv::append_deferred`] and
    /// awaits its flush (inline via `run_flush`, or detached via
    /// `detach_flush`).
    sealed: bool,
    /// The first `in_flight` buffer tokens were detached as a
    /// [`FlushWork`] snapshot that is compressing asynchronously. They
    /// remain readable here (attention, `len`, `nbytes`) until
    /// [`LayerKv::install_flush`] replaces them with the segment.
    in_flight: usize,
}

impl GearLayerKv {
    pub fn new(
        d: usize,
        n_heads: usize,
        method: Method,
        buffer: usize,
        prefill_rank: usize,
        decode_rank: usize,
    ) -> Self {
        assert!(!method.is_fp16(), "use DenseLayerKv for FP16");
        GearLayerKv {
            d,
            n_heads,
            method,
            buffer_cap: buffer.max(1),
            prefill_rank,
            decode_rank,
            seg_k: Vec::new(),
            seg_v: Vec::new(),
            buf_k: Vec::new(),
            buf_v: Vec::new(),
            buf_n: 0,
            seg_tokens: 0,
            sealed: false,
            in_flight: 0,
        }
    }

    /// Method with rank overridden for the given phase (prefill vs decode).
    fn method_with_rank(&self, rank: usize) -> Method {
        match self.method {
            Method::GearL { bits, backbone, .. } if rank > 0 => {
                Method::GearL { bits, backbone, r: rank }
            }
            Method::Gear { bits, backbone, s, .. } if rank > 0 => {
                Method::Gear { bits, backbone, s, r: rank }
            }
            m => m,
        }
    }

    fn compress_chunk(&mut self, k: Tensor, v: Tensor, rank: usize) {
        debug_assert_eq!(self.in_flight, 0, "segment order: install the in-flight flush first");
        let m = self.method_with_rank(rank);
        let cfg = GearConfig::new(m, self.n_heads);
        let ck = compress(&k, KvKind::Key, &cfg);
        let cv = compress(&v, KvKind::Value, &cfg);
        self.seg_tokens += k.rows();
        self.seg_k.push(ck);
        self.seg_v.push(cv);
    }

    /// Force-compress whatever is in the buffer (used by tests/analysis;
    /// the engine lets the cadence do it). Clears any deferred-flush seal.
    /// Refused while a detached flush is in flight: its rows sit at the
    /// front of the buffer and must become the *next* segment.
    pub fn flush_buffer(&mut self) {
        assert_eq!(
            self.in_flight, 0,
            "cannot inline-flush while a detached flush is in flight; install it first"
        );
        self.sealed = false;
        if self.buf_n == 0 {
            return;
        }
        let k = Tensor::new(&[self.buf_n, self.d], std::mem::take(&mut self.buf_k));
        let v = Tensor::new(&[self.buf_n, self.d], std::mem::take(&mut self.buf_v));
        self.buf_n = 0;
        self.compress_chunk(k, v, self.decode_rank);
    }

    pub fn n_segments(&self) -> usize {
        self.seg_k.len()
    }

    pub fn buffered_tokens(&self) -> usize {
        self.buf_n
    }

    /// Buffer tokens currently detached into an in-flight [`FlushWork`]
    /// (still readable here; they leave at `install_flush`).
    pub fn in_flight_tokens(&self) -> usize {
        self.in_flight
    }
}

impl LayerKv for GearLayerKv {
    fn ingest_prefill(&mut self, k: Tensor, v: Tensor, _attn_mass: Option<&[f32]>) {
        assert_eq!(k.cols(), self.d);
        assert_eq!(k.shape(), v.shape());
        self.compress_chunk(k, v, self.prefill_rank);
    }

    fn append(&mut self, k: &[f32], v: &[f32]) {
        // Inline-flush semantics: seal-and-flush in one call, so the
        // standalone cadence (and its tests) are unchanged.
        self.append_deferred(k, v);
        self.run_flush();
    }

    fn append_deferred(&mut self, k: &[f32], v: &[f32]) {
        // Self-heal: a seal left over from a caller that skipped the
        // commit point compresses now, before the new row lands.
        self.run_flush();
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        self.buf_k.extend(k.iter().map(|&x| to_f16_precision(x)));
        self.buf_v.extend(v.iter().map(|&x| to_f16_precision(x)));
        self.buf_n += 1;
        // In-flight rows are already spoken for by a detached job; only the
        // rows behind them count toward the next seal.
        if self.buf_n - self.in_flight >= self.buffer_cap {
            self.sealed = true;
        }
    }

    fn flush_pending(&self) -> bool {
        self.sealed
    }

    fn run_flush(&mut self) {
        if self.sealed {
            self.flush_buffer();
        }
    }

    fn detach_flush(&mut self) -> Option<FlushWork> {
        if !self.sealed {
            return None;
        }
        // The engine joins a request's outstanding flush before detaching a
        // new seal, so the whole buffer is the sealed region here.
        assert_eq!(self.in_flight, 0, "previous detached flush not yet installed");
        self.sealed = false;
        self.in_flight = self.buf_n;
        Some(FlushWork {
            k: Tensor::new(&[self.buf_n, self.d], self.buf_k.clone()),
            v: Tensor::new(&[self.buf_n, self.d], self.buf_v.clone()),
            method: self.method_with_rank(self.decode_rank),
            n_heads: self.n_heads,
        })
    }

    fn install_flush(&mut self, result: FlushResult) {
        let rows = result.k.rows;
        assert_eq!(rows, self.in_flight, "install does not match the in-flight detach");
        debug_assert_eq!(result.v.rows, rows);
        // The detached rows sit at the front of the buffer (they are the
        // oldest); the segment takes their place at the end of the segment
        // list, preserving oldest-first order ahead of the remaining rows.
        self.buf_k.drain(..rows * self.d);
        self.buf_v.drain(..rows * self.d);
        self.buf_n -= rows;
        self.in_flight = 0;
        self.seg_tokens += rows;
        self.seg_k.push(result.k);
        self.seg_v.push(result.v);
    }

    fn len(&self) -> usize {
        self.seg_tokens + self.buf_n
    }

    fn attend_scratch(
        &mut self,
        q: &[f32],
        n_heads: usize,
        scratch: &mut AttendScratch,
        out: &mut [f32],
    ) {
        let d = self.d;
        debug_assert_eq!(n_heads, self.n_heads);
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(out.len(), d);
        let dh = d / n_heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let total = self.len();

        // Split the scratch so score storage and per-segment kernel buffers
        // can be borrowed simultaneously.
        let AttendScratch { scores, seg: kscratch } = scratch;
        scores.clear();
        scores.resize(total * n_heads, 0.0);

        // Scores: fused against each compressed K segment, dense against buffer.
        let mut off = 0usize;
        for seg in &self.seg_k {
            seg.scores_into_scratch(
                q,
                n_heads,
                scale,
                kscratch,
                &mut scores[off * n_heads..(off + seg.rows) * n_heads],
            );
            off += seg.rows;
        }
        for t in 0..self.buf_n {
            let krow = &self.buf_k[t * d..(t + 1) * d];
            for h in 0..n_heads {
                scores[(off + t) * n_heads + h] =
                    scale * dot(&q[h * dh..(h + 1) * dh], &krow[h * dh..(h + 1) * dh]);
            }
        }

        softmax_heads(scores, total, n_heads);

        // Weighted value sum, fused per segment.
        out.fill(0.0);
        let mut off = 0usize;
        for seg in &self.seg_v {
            seg.weighted_sum_into_scratch(
                &scores[off * n_heads..(off + seg.rows) * n_heads],
                n_heads,
                kscratch,
                out,
            );
            off += seg.rows;
        }
        for t in 0..self.buf_n {
            let vrow = &self.buf_v[t * d..(t + 1) * d];
            for h in 0..n_heads {
                let p = scores[(off + t) * n_heads + h];
                let seg = h * dh..(h + 1) * dh;
                crate::tensor::ops::axpy(p, &vrow[seg.clone()], &mut out[seg]);
            }
        }
    }

    fn nbytes(&self) -> usize {
        let segs: usize = self.seg_k.iter().chain(&self.seg_v).map(|s| s.nbytes()).sum();
        segs + (self.buf_k.len() + self.buf_v.len()) * 2
    }

    fn step_growth_bound(&self) -> usize {
        // The appended token lands in the FP16 buffer (a K and a V row).
        let append = 4 * self.d;
        let m = self.method_with_rank(self.decode_rank);
        let seg_cost = |rows: usize| {
            crate::gear::size::predict(m, true, rows, self.d, self.n_heads).total()
                + crate::gear::size::predict(m, false, rows, self.d, self.n_heads).total()
        };
        let mut bound = append;
        // An in-flight detached flush installs its segment at this
        // request's next commit — inside the sweep this bound reserves for.
        // The install also *removes* the detached FP16 rows from the
        // buffer, but we stay conservative and do not credit that back.
        if self.in_flight > 0 {
            bound += seg_cost(self.in_flight);
        }
        // A deferred seal still pending from the previous sweep flushes
        // before or with this step (inline commit or append self-heal;
        // under the engine's detached cadence it is only *submitted* this
        // sweep and its install is covered by the next sweep's bound —
        // counting it now merely over-reserves, which is safe).
        if self.sealed {
            bound += seg_cost(self.buf_n - self.in_flight);
        }
        // Will this append fill (and this sweep flush) the buffer? After a
        // pending flush the buffer restarts empty; in-flight rows no longer
        // count toward the cap. The analytic size model is exact for every
        // method (`gear::size` pins predict == measured), but we stay
        // conservative and do not credit back the freed buffer rows — the
        // bound only has to never under-estimate.
        let buf_after = if self.sealed { 0 } else { self.buf_n - self.in_flight };
        if buf_after + 1 >= self.buffer_cap {
            bound += seg_cost(self.buffer_cap);
        }
        bound
    }

    fn breakdown(&self) -> SizeBreakdown {
        let mut b = SizeBreakdown::default();
        for seg in self.seg_k.iter().chain(&self.seg_v) {
            if let Some(q) = &seg.quant {
                b.quant_bytes += q.nbytes() - q.n_groups() * 4;
                b.meta_bytes += q.n_groups() * 4;
            }
            if let Some(sp) = &seg.sparse {
                b.sparse_bytes += sp.nbytes();
            }
            if let Some(lr) = &seg.lowrank {
                b.lowrank_bytes += lr.nbytes();
            }
            if let Some(dn) = &seg.dense {
                b.dense_bytes += dn.len() * 2;
            }
        }
        b.dense_bytes += (self.buf_k.len() + self.buf_v.len()) * 2;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::dense::DenseLayerKv;
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize, d: usize) -> (Tensor, Tensor) {
        (Tensor::randn(&[n, d], rng, 1.0), Tensor::randn(&[n, d], rng, 1.0))
    }

    #[test]
    fn buffer_flush_cadence() {
        let mut c = GearLayerKv::new(16, 2, Method::gear_default(4), 4, 4, 2);
        let mut rng = Rng::new(90);
        let (k, v) = fill(&mut rng, 1, 16);
        for i in 1..=9 {
            c.append(k.row(0), v.row(0));
            assert_eq!(c.len(), i);
        }
        // 9 appends with n_b=4: two flushes (at 4 and 8), 1 buffered.
        assert_eq!(c.n_segments(), 2);
        assert_eq!(c.buffered_tokens(), 1);
    }

    #[test]
    fn prefill_compresses_immediately() {
        let mut c = GearLayerKv::new(32, 4, Method::gear_default(2), 20, 4, 2);
        let mut rng = Rng::new(91);
        let (k, v) = fill(&mut rng, 64, 32);
        c.ingest_prefill(k, v, None);
        assert_eq!(c.n_segments(), 1);
        assert_eq!(c.buffered_tokens(), 0);
        assert_eq!(c.len(), 64);
        // Compressed well below FP16.
        assert!(c.nbytes() < 2 * 64 * 32 * 2);
    }

    #[test]
    fn attend_matches_dense_cache_closely_at_8bit() {
        // 8-bit GEAR attention ≈ FP16 attention on the same tokens.
        let mut rng = Rng::new(92);
        let (d, h, n) = (32, 4, 48);
        let (k, v) = fill(&mut rng, n, d);
        let mut dense = DenseLayerKv::new(d);
        dense.ingest_prefill(k.clone(), v.clone(), None);
        let mut gear = GearLayerKv::new(
            d,
            h,
            Method::Gear {
                bits: 8,
                backbone: crate::gear::compose::Backbone::Kivi(16),
                s: 0.02,
                r: 4,
            },
            20,
            4,
            2,
        );
        gear.ingest_prefill(k, v, None);

        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut o1 = vec![0.0f32; d];
        let mut o2 = vec![0.0f32; d];
        dense.attend(&q, h, &mut o1);
        gear.attend(&q, h, &mut o2);
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 0.05, "dense {a} vs gear {b}");
        }
    }

    #[test]
    fn gear_attend_beats_quant_only_at_2bit() {
        // The error-reduction components must show up in attention outputs,
        // not just matrix reconstruction.
        let mut rng = Rng::new(93);
        let (d, h, n) = (32, 4, 64);
        // Heavy-tailed channel scales (Key regime).
        let mut k = Tensor::zeros(&[n, d]);
        for j in 0..d {
            let s = (rng.normal_f32() * 1.2).exp();
            for i in 0..n {
                k.data_mut()[i * d + j] = rng.normal_f32() * s;
            }
        }
        let v = Tensor::randn(&[n, d], &mut rng, 1.0);
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

        let mut exact = DenseLayerKv::new(d);
        exact.ingest_prefill(k.clone(), v.clone(), None);
        let mut o_exact = vec![0.0f32; d];
        exact.attend(&q, h, &mut o_exact);

        let bb = crate::gear::compose::Backbone::Kivi(16);
        let mut run = |m: Method| {
            let mut c = GearLayerKv::new(d, h, m, 20, 4, 2);
            c.ingest_prefill(k.clone(), v.clone(), None);
            let mut o = vec![0.0f32; d];
            c.attend(&q, h, &mut o);
            crate::tensor::ops::fro_dist(&o_exact, &o)
        };
        let e_quant = run(Method::QuantOnly { bits: 2, backbone: bb });
        let e_gear = run(Method::Gear { bits: 2, backbone: bb, s: 0.02, r: 4 });
        assert!(e_gear < e_quant, "gear {e_gear} !< quant {e_quant}");
    }

    #[test]
    fn step_growth_bound_covers_append_and_flush() {
        // The engine's step-headroom reservation relies on this bound never
        // under-estimating one append's growth, including flush sweeps —
        // exercise small buffers and high decode ranks (chunk overhead
        // dominates there).
        let mut rng = Rng::new(95);
        for (method, buffer, decode_rank) in [
            (Method::gear_default(2), 4, 2),
            (Method::gear_l_default(4), 2, 4),
            (
                Method::QuantOnly {
                    bits: 2,
                    backbone: crate::gear::compose::Backbone::Kivi(16),
                },
                3,
                0,
            ),
        ] {
            let mut c = GearLayerKv::new(32, 4, method, buffer, 4, decode_rank);
            let (k, v) = fill(&mut rng, 1, 32);
            for step in 0..13 {
                let before = c.nbytes();
                let bound = c.step_growth_bound();
                c.append(k.row(0), v.row(0));
                assert!(
                    c.nbytes() <= before + bound,
                    "step {step} {method:?}: {} > {before} + {bound}",
                    c.nbytes()
                );
            }
        }
    }

    #[test]
    fn deferred_append_seals_without_compressing() {
        let mut c = GearLayerKv::new(16, 2, Method::gear_default(4), 4, 4, 2);
        let mut rng = Rng::new(96);
        let (k, v) = fill(&mut rng, 1, 16);
        for i in 1..=4 {
            assert!(!c.flush_pending());
            c.append_deferred(k.row(0), v.row(0));
            assert_eq!(c.len(), i);
        }
        // Buffer full: sealed, not compressed — bytes are still all FP16.
        assert!(c.flush_pending());
        assert_eq!(c.n_segments(), 0);
        assert_eq!(c.buffered_tokens(), 4);
        assert_eq!(c.nbytes(), 2 * 4 * 16 * 2);
        c.run_flush();
        assert!(!c.flush_pending());
        assert_eq!(c.n_segments(), 1);
        assert_eq!(c.buffered_tokens(), 0);
        assert_eq!(c.len(), 4);
        // Idempotent when nothing is pending.
        let bytes = c.nbytes();
        c.run_flush();
        assert_eq!(c.nbytes(), bytes);
    }

    #[test]
    fn deferred_and_inline_cadence_produce_identical_bytes() {
        // Same rows through both cadences -> same segments, same bytes:
        // the engine's deferred path changes *when* compression runs, not
        // what it produces.
        let mut rng = Rng::new(97);
        let rows: Vec<(Tensor, Tensor)> = (0..9).map(|_| fill(&mut rng, 1, 16)).collect();
        let run = |deferred: bool| {
            let mut c = GearLayerKv::new(16, 2, Method::gear_default(4), 4, 4, 2);
            for (k, v) in &rows {
                if deferred {
                    c.append_deferred(k.row(0), v.row(0));
                    c.run_flush(); // the engine's commit point
                } else {
                    c.append(k.row(0), v.row(0));
                }
            }
            (c.n_segments(), c.buffered_tokens(), c.nbytes(), c.breakdown().total())
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn sealed_buffer_self_heals_on_next_append() {
        // A caller that never runs the commit point (standalone decode
        // loop via append_deferred) must not grow the buffer past its
        // capacity: the pending flush runs at the next append.
        let mut c = GearLayerKv::new(16, 2, Method::gear_default(4), 4, 4, 2);
        let mut rng = Rng::new(98);
        let (k, v) = fill(&mut rng, 1, 16);
        for _ in 0..4 {
            c.append_deferred(k.row(0), v.row(0));
        }
        assert!(c.flush_pending());
        c.append_deferred(k.row(0), v.row(0));
        assert_eq!(c.n_segments(), 1);
        assert_eq!(c.buffered_tokens(), 1);
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn detached_flush_matches_inline_cadence() {
        // The engine's detached flush (detach → compress off-layer →
        // install) must produce bit-identical segments and bytes to the
        // inline cadence: compression is a pure function of the sealed
        // rows, the method, and the shape-derived seed.
        let mut rng = Rng::new(100);
        let rows: Vec<(Tensor, Tensor)> = (0..9).map(|_| fill(&mut rng, 1, 16)).collect();
        let run = |detached: bool| {
            let mut c = GearLayerKv::new(16, 2, Method::gear_default(4), 4, 4, 2);
            let mut in_flight: Option<FlushResult> = None;
            for (k, v) in &rows {
                c.append_deferred(k.row(0), v.row(0));
                // Commit point: land the previous sweep's job before
                // detaching the new seal — the engine's join-then-submit
                // order.
                if detached {
                    if let Some(r) = in_flight.take() {
                        c.install_flush(r);
                    }
                    if let Some(w) = c.detach_flush() {
                        in_flight = Some(w.compress());
                    }
                } else {
                    c.run_flush();
                }
            }
            if let Some(r) = in_flight.take() {
                c.install_flush(r);
            }
            (c.n_segments(), c.buffered_tokens(), c.nbytes(), c.breakdown().total())
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn detached_rows_stay_readable_until_install() {
        let mut rng = Rng::new(101);
        let (d, h) = (32, 4);
        let rows: Vec<(Tensor, Tensor)> = (0..4).map(|_| fill(&mut rng, 1, d)).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

        let mut inline = GearLayerKv::new(d, h, Method::gear_default(4), 4, 4, 2);
        let mut eng = GearLayerKv::new(d, h, Method::gear_default(4), 4, 4, 2);
        for (k, v) in &rows {
            inline.append(k.row(0), v.row(0));
            eng.append_deferred(k.row(0), v.row(0));
        }
        // Buffer full: flushed inline on one cadence, detached on the other.
        let w = eng.detach_flush().unwrap();
        assert_eq!(w.rows(), 4);
        assert_eq!(eng.in_flight_tokens(), 4);
        // While the job is in flight the rows stay fully readable: token
        // count and bytes unchanged (still dense FP16), attention answers.
        assert_eq!(eng.len(), 4);
        assert_eq!(eng.nbytes(), 2 * 4 * d * 2);
        let mut o = vec![0.0f32; d];
        eng.attend(&q, h, &mut o);
        assert!(o.iter().all(|x| x.is_finite()));
        // Install: state becomes bit-identical to the inline cadence.
        eng.install_flush(w.compress());
        assert_eq!(eng.in_flight_tokens(), 0);
        assert_eq!(eng.n_segments(), 1);
        assert_eq!(eng.buffered_tokens(), 0);
        assert_eq!(eng.len(), 4);
        assert_eq!(eng.nbytes(), inline.nbytes());
        let mut o1 = vec![0.0f32; d];
        let mut o2 = vec![0.0f32; d];
        inline.attend(&q, h, &mut o1);
        eng.attend(&q, h, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn step_growth_bound_covers_detached_cadence() {
        // The engine reserves the bound, appends, then at commit installs
        // the previous sweep's detached job and detaches the new seal.
        // Growth across that whole window must stay within the bound —
        // including cap-1 buffers where an install and a fresh detach meet
        // at every commit.
        let mut rng = Rng::new(102);
        for (method, buffer, decode_rank) in [
            (Method::gear_default(2), 4, 2),
            (Method::gear_l_default(4), 2, 4),
            (Method::gear_default(4), 1, 2),
        ] {
            let mut c = GearLayerKv::new(32, 4, method, buffer, 4, decode_rank);
            let (k, v) = fill(&mut rng, 1, 32);
            let mut in_flight: Option<FlushResult> = None;
            for step in 0..13 {
                let before = c.nbytes();
                let bound = c.step_growth_bound();
                c.append_deferred(k.row(0), v.row(0));
                if let Some(r) = in_flight.take() {
                    c.install_flush(r);
                }
                if let Some(w) = c.detach_flush() {
                    in_flight = Some(w.compress());
                }
                assert!(
                    c.nbytes() <= before + bound,
                    "detached cadence step {step} {method:?}: {} > {before} + {bound}",
                    c.nbytes()
                );
            }
        }
    }

    #[test]
    fn step_growth_bound_covers_deferred_sweeps() {
        // The engine reserves the bound before the decode step, then runs
        // append_deferred + commit-point flush; growth across that whole
        // sweep must stay within the bound — including with a stale seal
        // pending (standalone callers) and with cap-1 buffers that seal
        // every append.
        let mut rng = Rng::new(99);
        for (method, buffer, decode_rank) in [
            (Method::gear_default(2), 4, 2),
            (Method::gear_l_default(4), 2, 4),
            (Method::gear_default(4), 1, 2),
        ] {
            let mut c = GearLayerKv::new(32, 4, method, buffer, 4, decode_rank);
            let (k, v) = fill(&mut rng, 1, 32);
            // Engine cadence: reserve -> append -> flush at commit.
            for step in 0..13 {
                let before = c.nbytes();
                let bound = c.step_growth_bound();
                c.append_deferred(k.row(0), v.row(0));
                c.run_flush();
                assert!(
                    c.nbytes() <= before + bound,
                    "engine cadence step {step} {method:?}: {} > {before} + {bound}",
                    c.nbytes()
                );
            }
            // No-commit cadence: the seal heals inside the next append.
            for step in 0..13 {
                let before = c.nbytes();
                let bound = c.step_growth_bound();
                c.append_deferred(k.row(0), v.row(0));
                assert!(
                    c.nbytes() <= before + bound,
                    "self-heal cadence step {step} {method:?}: {} > {before} + {bound}",
                    c.nbytes()
                );
            }
        }
    }

    #[test]
    fn nbytes_tracks_buffer_and_segments() {
        let mut c = GearLayerKv::new(16, 2, Method::gear_l_default(2), 4, 4, 2);
        assert_eq!(c.nbytes(), 0);
        let mut rng = Rng::new(94);
        let (k, v) = fill(&mut rng, 1, 16);
        c.append(k.row(0), v.row(0));
        // One buffered token: 2 rows (K+V) × 16 × 2 bytes.
        assert_eq!(c.nbytes(), 2 * 16 * 2);
        for _ in 0..3 {
            c.append(k.row(0), v.row(0));
        }
        assert_eq!(c.buffered_tokens(), 0);
        assert!(c.nbytes() > 0);
        let bd = c.breakdown();
        assert_eq!(bd.total(), c.nbytes());
        assert!(bd.quant_bytes > 0);
        assert!(bd.lowrank_bytes > 0);
        assert_eq!(bd.sparse_bytes, 0); // GEAR-L has no sparse component
    }
}
