//! FP16 dense per-layer KV cache — the paper's uncompressed baseline.
//!
//! Values are rounded through FP16 precision on store and accounted at
//! 2 bytes per entry, matching the FP16-cache baseline of the paper.

use crate::gear::size::SizeBreakdown;
use crate::tensor::ops::dot;
use crate::tensor::Tensor;
use crate::util::f16::to_f16_precision;

use super::{AttendScratch, LayerKv};

pub struct DenseLayerKv {
    d: usize,
    /// Row-major n×d, FP16-rounded.
    k: Vec<f32>,
    v: Vec<f32>,
    n: usize,
}

impl DenseLayerKv {
    pub fn new(d: usize) -> Self {
        DenseLayerKv { d, k: Vec::new(), v: Vec::new(), n: 0 }
    }

    fn push_rows(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len() % self.d, 0);
        self.k.extend(k.iter().map(|&x| to_f16_precision(x)));
        self.v.extend(v.iter().map(|&x| to_f16_precision(x)));
        self.n += k.len() / self.d;
    }

    /// Direct row access for analysis tools.
    pub fn k_row(&self, t: usize) -> &[f32] {
        &self.k[t * self.d..(t + 1) * self.d]
    }
}

impl LayerKv for DenseLayerKv {
    fn ingest_prefill(&mut self, k: Tensor, v: Tensor, _attn_mass: Option<&[f32]>) {
        assert_eq!(k.cols(), self.d);
        assert_eq!(k.shape(), v.shape());
        self.push_rows(k.data(), v.data());
    }

    fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        self.push_rows(k, v);
    }

    fn len(&self) -> usize {
        self.n
    }

    fn attend_scratch(
        &mut self,
        q: &[f32],
        n_heads: usize,
        scratch: &mut AttendScratch,
        out: &mut [f32],
    ) {
        let (n, d) = (self.n, self.d);
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(out.len(), d);
        let dh = d / n_heads;
        let scale = 1.0 / (dh as f32).sqrt();

        let scores = &mut scratch.scores;
        scores.clear();
        scores.resize(n * n_heads, 0.0);
        for t in 0..n {
            let krow = &self.k[t * d..(t + 1) * d];
            for h in 0..n_heads {
                scores[t * n_heads + h] =
                    scale * dot(&q[h * dh..(h + 1) * dh], &krow[h * dh..(h + 1) * dh]);
            }
        }
        // Per-head softmax over the token axis (stride n_heads).
        softmax_heads(scores, n, n_heads);

        out.fill(0.0);
        for t in 0..n {
            let vrow = &self.v[t * d..(t + 1) * d];
            for h in 0..n_heads {
                let p = scores[t * n_heads + h];
                let seg = h * dh..(h + 1) * dh;
                crate::tensor::ops::axpy(p, &vrow[seg.clone()], &mut out[seg]);
            }
        }
    }

    fn nbytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 2
    }

    fn step_growth_bound(&self) -> usize {
        // One appended token: a K row and a V row at FP16.
        4 * self.d
    }

    fn breakdown(&self) -> SizeBreakdown {
        SizeBreakdown { dense_bytes: self.nbytes(), ..Default::default() }
    }
}

/// Softmax over the token axis for interleaved multi-head scores
/// (`s[t*H + h]`), numerically stable per head.
pub fn softmax_heads(scores: &mut [f32], n: usize, n_heads: usize) {
    debug_assert_eq!(scores.len(), n * n_heads);
    if n == 0 {
        return;
    }
    // Gather per-head columns into a scratch-free two-pass computation.
    for h in 0..n_heads {
        let mut max = f32::NEG_INFINITY;
        for t in 0..n {
            max = max.max(scores[t * n_heads + h]);
        }
        let mut sum = 0.0f32;
        for t in 0..n {
            let e = (scores[t * n_heads + h] - max).exp();
            scores[t * n_heads + h] = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for t in 0..n {
            scores[t * n_heads + h] *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn attend_single_token_returns_its_value() {
        let mut c = DenseLayerKv::new(8);
        let k = vec![1.0f32; 8];
        let v: Vec<f32> = (0..8).map(|i| i as f32).collect();
        c.append(&k, &v);
        let mut out = vec![0.0f32; 8];
        c.attend(&[0.5; 8], 2, &mut out);
        // Softmax over one token = 1 -> out == v (up to fp16 rounding).
        for (o, vv) in out.iter().zip(&v) {
            assert!((o - vv).abs() < 1e-2);
        }
    }

    #[test]
    fn attention_weights_favor_aligned_key() {
        let mut c = DenseLayerKv::new(4);
        // token 0 key aligned with query, token 1 anti-aligned.
        c.append(&[10.0, 0.0, 10.0, 0.0], &[1.0, 1.0, 1.0, 1.0]);
        c.append(&[-10.0, 0.0, -10.0, 0.0], &[-1.0, -1.0, -1.0, -1.0]);
        let mut out = vec![0.0f32; 4];
        c.attend(&[10.0, 0.0, 10.0, 0.0], 1, &mut out);
        for o in &out {
            assert!(*o > 0.99, "{out:?}");
        }
    }

    #[test]
    fn prefill_then_append_consistent() {
        let mut rng = Rng::new(80);
        let d = 16;
        let k = Tensor::randn(&[5, d], &mut rng, 1.0);
        let v = Tensor::randn(&[5, d], &mut rng, 1.0);
        let mut c = DenseLayerKv::new(d);
        c.ingest_prefill(k.clone(), v.clone(), None);
        assert_eq!(c.len(), 5);
        c.append(k.row(0), v.row(0));
        assert_eq!(c.len(), 6);
        assert_eq!(c.nbytes(), 2 * 6 * d * 2);
    }

    #[test]
    fn softmax_heads_normalizes_each_head() {
        let mut s = vec![0.1f32, 5.0, 0.2, -3.0, 0.3, 0.0]; // n=3, H=2
        softmax_heads(&mut s, 3, 2);
        let h0: f32 = (0..3).map(|t| s[t * 2]).sum();
        let h1: f32 = (0..3).map(|t| s[t * 2 + 1]).sum();
        assert!((h0 - 1.0).abs() < 1e-5);
        assert!((h1 - 1.0).abs() < 1e-5);
    }
}
