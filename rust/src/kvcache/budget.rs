//! Device-memory budget manager.
//!
//! Models the GPU memory constraint of the paper's efficiency study
//! (Figures 3b/3c, Tables 6/7): a fixed byte budget shared by model weights
//! and all live KV caches. The batcher consults [`MemoryBudget`] before
//! admitting requests; `reserve`/`release` track real cache bytes as they
//! grow and shrink.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread-safe byte budget with peak tracking.
#[derive(Debug)]
pub struct MemoryBudget {
    capacity: usize,
    used: AtomicUsize,
    peak: AtomicUsize,
}

impl MemoryBudget {
    pub fn new(capacity: usize) -> Self {
        MemoryBudget { capacity, used: AtomicUsize::new(0), peak: AtomicUsize::new(0) }
    }

    /// Unlimited budget (accuracy experiments).
    pub fn unlimited() -> Self {
        Self::new(usize::MAX)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn used(&self) -> usize {
        self.used.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn available(&self) -> usize {
        self.capacity.saturating_sub(self.used())
    }

    /// Try to reserve `bytes`; returns false if it would exceed capacity.
    pub fn try_reserve(&self, bytes: usize) -> bool {
        let mut cur = self.used.load(Ordering::Relaxed);
        loop {
            let Some(next) = cur.checked_add(bytes) else { return false };
            if next > self.capacity {
                return false;
            }
            match self.used.compare_exchange_weak(cur, next, Ordering::SeqCst, Ordering::Relaxed) {
                Ok(_) => {
                    self.peak.fetch_max(next, Ordering::SeqCst);
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Release previously-reserved bytes.
    pub fn release(&self, bytes: usize) {
        let prev = self.used.fetch_sub(bytes, Ordering::SeqCst);
        debug_assert!(prev >= bytes, "releasing {bytes} > used {prev}");
    }

    /// Adjust a reservation from `old` to `new` bytes (cache growth).
    /// Returns false (and leaves the reservation at `old`) on overflow.
    pub fn adjust(&self, old: usize, new: usize) -> bool {
        if new >= old {
            self.try_reserve(new - old)
        } else {
            self.release(old - new);
            true
        }
    }

    pub fn reset_peak(&self) {
        self.peak.store(self.used(), Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_and_release() {
        let b = MemoryBudget::new(100);
        assert!(b.try_reserve(60));
        assert!(!b.try_reserve(50));
        assert!(b.try_reserve(40));
        assert_eq!(b.used(), 100);
        b.release(100);
        assert_eq!(b.used(), 0);
        assert_eq!(b.peak(), 100);
    }

    #[test]
    fn adjust_grows_and_shrinks() {
        let b = MemoryBudget::new(100);
        assert!(b.try_reserve(10));
        assert!(b.adjust(10, 50));
        assert_eq!(b.used(), 50);
        assert!(b.adjust(50, 20));
        assert_eq!(b.used(), 20);
        assert!(!b.adjust(20, 200));
        assert_eq!(b.used(), 20);
    }

    #[test]
    fn concurrent_reservations_never_exceed_capacity() {
        use std::sync::Arc;
        let b = Arc::new(MemoryBudget::new(1000));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                let mut got = 0usize;
                for _ in 0..1000 {
                    if b.try_reserve(7) {
                        got += 7;
                    }
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total <= 1000);
        assert_eq!(b.used(), total);
        assert!(b.peak() <= 1000);
    }

    #[test]
    fn unlimited_never_rejects() {
        let b = MemoryBudget::unlimited();
        assert!(b.try_reserve(usize::MAX / 2));
    }
}
