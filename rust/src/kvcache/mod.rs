//! KV-cache management for the serving engine.
//!
//! Each request owns one [`RequestCache`] (a stack of per-layer caches); the
//! engine's memory manager sums `nbytes()` across live requests against a
//! device byte budget (the V100-16GB analogue — see DESIGN.md §3).
//!
//! Cache implementations:
//! * [`dense::DenseLayerKv`] — FP16 baseline.
//! * [`gear_cache::GearLayerKv`] — compressed segments + streaming buffer
//!   (the paper's system).
//! * [`crate::baselines::h2o::H2oLayerKv`] — token-dropping baseline.

pub mod budget;
pub mod dense;
pub mod gear_cache;

use crate::gear::attend::SegScratch;
use crate::gear::compose::{compress, CompressedMatrix, GearConfig};
use crate::gear::size::SizeBreakdown;
use crate::gear::{KvKind, Method};
use crate::tensor::Tensor;

/// Reusable attention scratch: every `Vec` the attend hot path needs, owned
/// by the caller so batch-executor workers never allocate inside the
/// per-layer attend loop. One instance per worker; buffers grow to the
/// largest cache seen.
#[derive(Debug, Default, Clone)]
pub struct AttendScratch {
    /// Interleaved multi-head scores `s[t*H + h]` across the whole cache.
    pub scores: Vec<f32>,
    /// Per-segment kernel scratch (dequant row, `Bᵀq` projection, plan).
    pub seg: SegScratch,
}

/// An owned, self-contained compression job detached from a sealed
/// streaming buffer by [`LayerKv::detach_flush`].
///
/// The job carries a *snapshot* of the sealed rows: the layer keeps its own
/// copy readable (attention and byte accounting are unaffected while the
/// job is in flight), and [`FlushWork::compress`] is a pure function of this
/// data — same rows, same method, same deterministic seed, same segments —
/// so *where* and *when* it runs cannot change the result. That is what
/// lets the engine run it on a pool worker concurrently with the next
/// sweep's prefill and decode, or steal it inline at the join point in
/// `ExecMode::Sequential`, and still be bit-identical between the two.
pub struct FlushWork {
    /// Sealed K rows (rows × d), FP16-rounded exactly as buffered.
    pub k: Tensor,
    /// Sealed V rows (rows × d).
    pub v: Tensor,
    /// Compression method with the decode rank already applied.
    pub method: Method,
    pub n_heads: usize,
}

impl FlushWork {
    /// Number of sealed token rows this job will compress.
    pub fn rows(&self) -> usize {
        self.k.rows()
    }

    /// Run the GEAR compression (quant backbone + low-rank residual +
    /// sparse outliers, per [`Method`]). Pure and deterministic: the RNG
    /// inside is seeded from the config and matrix shape only. When the
    /// flush lane runs traced, the two `compress` calls below stage one
    /// quality probe each (K first, then V — the order
    /// [`crate::trace::Quality`] attribution relies on).
    pub fn compress(self) -> FlushResult {
        let cfg = GearConfig::new(self.method, self.n_heads);
        FlushResult {
            k: compress(&self.k, KvKind::Key, &cfg),
            v: compress(&self.v, KvKind::Value, &cfg),
        }
    }
}

/// The compressed segments produced by [`FlushWork::compress`], handed back
/// to the owning layer via [`LayerKv::install_flush`].
pub struct FlushResult {
    pub k: CompressedMatrix,
    pub v: CompressedMatrix,
}

/// Per-layer KV cache: stores K/V rows and answers fused attention queries.
pub trait LayerKv: Send {
    /// Ingest the prefill-phase K and V matrices (n × d each) in one shot.
    /// `attn_mass`, when provided, is the accumulated attention mass each
    /// prompt token received during prefill (length n) — score-tracking
    /// caches (H₂O) use it to seed their heavy-hitter statistics.
    fn ingest_prefill(&mut self, k: Tensor, v: Tensor, attn_mass: Option<&[f32]>);

    /// Append one decoded token's k and v vectors (d each).
    fn append(&mut self, k: &[f32], v: &[f32]);

    /// Append like [`Self::append`], but *defer* any compression the
    /// append would trigger: a streaming buffer that reaches capacity is
    /// sealed and reported through [`Self::flush_pending`] instead of
    /// compressing inline. The engine's decode sweep appends through this,
    /// then detaches every seal as an asynchronous job at its commit point
    /// ([`Self::detach_flush`]) so the compression overlaps the next
    /// sweep's prefill and decode on the executor pool, joining only when
    /// byte accounting must observe the result ([`Self::install_flush`]).
    /// A sealed buffer left behind by a caller that never runs a commit
    /// point is flushed at the next append — self-healing — so standalone
    /// decode loops stay correct. Caches with no deferred work (FP16
    /// dense, H₂O) treat this exactly as [`Self::append`].
    fn append_deferred(&mut self, k: &[f32], v: &[f32]) {
        self.append(k, v);
    }

    /// Whether a sealed buffer is waiting for [`Self::run_flush`].
    fn flush_pending(&self) -> bool {
        false
    }

    /// Run any deferred compression sealed by [`Self::append_deferred`]
    /// inline, on the calling thread (no-op when nothing is pending). This
    /// is the *synchronous* flush used by standalone decode loops and the
    /// self-heal path; the engine instead detaches the work
    /// ([`Self::detach_flush`]) so it can overlap the next sweep.
    fn run_flush(&mut self) {}

    /// Detach the sealed buffer as an owned [`FlushWork`] job, or `None`
    /// when nothing is sealed (including caches with no deferred work).
    ///
    /// The detached rows *stay readable in the layer* — `len`, `nbytes`,
    /// and attention are unaffected while the job is in flight — but they
    /// are marked in-flight: the layer refuses inline flushes until the
    /// job's result comes back through [`Self::install_flush`], because a
    /// segment compressed out of order would corrupt the oldest-first
    /// segment layout. At most one job per layer may be in flight; the
    /// engine guarantees this by joining a request's outstanding flushes at
    /// its next commit, before detaching new seals.
    fn detach_flush(&mut self) -> Option<FlushWork> {
        None
    }

    /// Install the compressed segments a detached [`FlushWork`] produced:
    /// the in-flight rows leave the FP16 buffer and the segments take their
    /// place. Only meaningful after [`Self::detach_flush`] returned a job.
    fn install_flush(&mut self, result: FlushResult) {
        let _ = result;
        unreachable!("this cache has no deferred flush work to install");
    }

    /// Number of tokens currently represented (dropped tokens excluded).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Multi-head causal attention of query `q` (d, heads concatenated)
    /// against all stored tokens; writes the context vector into `out` (d).
    /// `&mut self` because score-tracking caches (H₂O) update statistics.
    /// All intermediate buffers live in `scratch`, which the batched decode
    /// plane reuses across requests, layers, and sweeps.
    fn attend_scratch(
        &mut self,
        q: &[f32],
        n_heads: usize,
        scratch: &mut AttendScratch,
        out: &mut [f32],
    );

    /// Convenience form of [`Self::attend_scratch`] with a throwaway
    /// scratch — fine for tests and analysis, not for the sweep hot loop.
    fn attend(&mut self, q: &[f32], n_heads: usize, out: &mut [f32]) {
        let mut scratch = AttendScratch::default();
        self.attend_scratch(q, n_heads, &mut scratch, out);
    }

    /// Current real storage bytes.
    fn nbytes(&self) -> usize;

    /// Conservative upper bound on how much [`Self::nbytes`] can grow from
    /// appending one token — including any compression flush the append may
    /// trigger. The engine pre-reserves this for every active request
    /// before a decode sweep executes, so real cache bytes can no longer
    /// overshoot the byte budget mid-sweep.
    fn step_growth_bound(&self) -> usize;

    /// Component breakdown (Fig 6).
    fn breakdown(&self) -> SizeBreakdown;
}

/// How to build caches for a request — the serving-level compression policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CacheSpec {
    /// Uncompressed FP16 cache.
    Fp16,
    /// Compressed cache with the paper's streaming-buffer strategy.
    Compressed {
        method: Method,
        /// Streaming buffer capacity n_b (compression cadence).
        buffer: usize,
        /// Rank for the prefill-phase compression (paper r_p = 4).
        prefill_rank: usize,
        /// Rank for each decoded buffer chunk (paper r_g = 2).
        decode_rank: usize,
    },
    /// H₂O heavy-hitter token dropping at FP16.
    H2o {
        /// Fraction of tokens kept (paper evaluates 50%).
        keep: f64,
        /// Recent tokens always kept.
        recent: usize,
    },
}

impl CacheSpec {
    /// The paper's standard GEAR serving configuration at `bits`.
    pub fn gear(bits: u8) -> CacheSpec {
        CacheSpec::Compressed {
            method: Method::gear_default(bits),
            buffer: 20,
            prefill_rank: 4,
            decode_rank: 2,
        }
    }

    /// The paper's GEAR-L serving configuration at `bits`.
    pub fn gear_l(bits: u8) -> CacheSpec {
        CacheSpec::Compressed {
            method: Method::gear_l_default(bits),
            buffer: 20,
            prefill_rank: 4,
            decode_rank: 2,
        }
    }

    /// A plain quantization serving configuration (KIVI-style buffering).
    pub fn quant(method: Method, buffer: usize) -> CacheSpec {
        CacheSpec::Compressed { method, buffer, prefill_rank: 0, decode_rank: 0 }
    }

    /// Parse a CLI spec string. Accepted forms, with `<b>` any of the
    /// paper's bit widths 2, 4, or 8:
    ///
    /// * `fp16` — uncompressed baseline;
    /// * `gear-<b>` / `gear-l-<b>` — the paper's GEAR / GEAR-L recipes
    ///   (e.g. `gear-2`, `gear-8`, `gear-l-8`);
    /// * `kivi-<b>`, `kcvt-<b>`, `per-token-<b>` — quantization-only
    ///   backbones (e.g. `kivi-8`);
    /// * `h2o-<pct>` — H₂O token dropping at `<pct>`% kept (e.g. `h2o-50`).
    ///
    /// Parsing is case-insensitive. [`Self::canonical_name`] inverts this
    /// mapping for specs that came from it.
    pub fn parse(s: &str) -> Option<CacheSpec> {
        use crate::gear::compose::Backbone;
        let s = s.to_ascii_lowercase();
        let bits = |suffix: &str| suffix.parse::<u8>().ok().filter(|b| matches!(b, 2 | 4 | 8));
        Some(match s.as_str() {
            "fp16" => CacheSpec::Fp16,
            _ if s.starts_with("gear-l-") => CacheSpec::gear_l(bits(&s[7..])?),
            _ if s.starts_with("gear-") => CacheSpec::gear(bits(&s[5..])?),
            _ if s.starts_with("kivi-") => CacheSpec::quant(
                Method::QuantOnly { bits: bits(&s[5..])?, backbone: Backbone::Kivi(64) },
                64,
            ),
            _ if s.starts_with("kcvt-") => CacheSpec::quant(
                Method::QuantOnly { bits: bits(&s[5..])?, backbone: Backbone::Kcvt },
                20,
            ),
            _ if s.starts_with("per-token-") => CacheSpec::quant(
                Method::QuantOnly { bits: bits(&s[10..])?, backbone: Backbone::PerTokenGroup(64) },
                64,
            ),
            _ if s.starts_with("h2o-") => {
                let pct: f64 = s[4..].parse().ok()?;
                CacheSpec::H2o { keep: (pct / 100.0).clamp(0.01, 1.0), recent: 16 }
            }
            _ => return None,
        })
    }

    pub fn label(&self) -> String {
        match self {
            CacheSpec::Fp16 => "FP16".into(),
            CacheSpec::Compressed { method, .. } => method.label(),
            CacheSpec::H2o { keep, .. } => format!("H2O keep={:.0}%", keep * 100.0),
        }
    }

    /// The CLI string [`Self::parse`] would turn back into exactly this
    /// spec, or `None` for configurations `parse` cannot express (custom
    /// buffers, ranks, or backbone group sizes).
    pub fn canonical_name(&self) -> Option<String> {
        use crate::gear::compose::Backbone;
        let name = match *self {
            CacheSpec::Fp16 => "fp16".to_string(),
            CacheSpec::H2o { keep, .. } => format!("h2o-{:.0}", keep * 100.0),
            CacheSpec::Compressed { method, .. } => match method {
                Method::Gear { bits, .. } => format!("gear-{bits}"),
                Method::GearL { bits, .. } => format!("gear-l-{bits}"),
                Method::QuantOnly { bits, backbone: Backbone::Kivi(64) } => format!("kivi-{bits}"),
                Method::QuantOnly { bits, backbone: Backbone::Kcvt } => format!("kcvt-{bits}"),
                Method::QuantOnly { bits, backbone: Backbone::PerTokenGroup(64) } => {
                    format!("per-token-{bits}")
                }
                _ => return None,
            },
        };
        // Canonical only when it round-trips to this exact spec.
        (CacheSpec::parse(&name) == Some(*self)).then_some(name)
    }

    /// Build one layer's cache.
    pub fn new_layer(&self, d_model: usize, n_heads: usize) -> Box<dyn LayerKv> {
        match *self {
            CacheSpec::Fp16 => Box::new(dense::DenseLayerKv::new(d_model)),
            CacheSpec::Compressed { method, buffer, prefill_rank, decode_rank } => {
                Box::new(gear_cache::GearLayerKv::new(
                    d_model,
                    n_heads,
                    method,
                    buffer,
                    prefill_rank,
                    decode_rank,
                ))
            }
            CacheSpec::H2o { keep, recent } => {
                Box::new(crate::baselines::h2o::H2oLayerKv::new(d_model, keep, recent))
            }
        }
    }
}

/// All layers of one request's cache.
pub struct RequestCache {
    pub layers: Vec<Box<dyn LayerKv>>,
}

impl RequestCache {
    pub fn new(spec: &CacheSpec, n_layers: usize, d_model: usize, n_heads: usize) -> Self {
        RequestCache {
            layers: (0..n_layers).map(|_| spec.new_layer(d_model, n_heads)).collect(),
        }
    }

    pub fn nbytes(&self) -> usize {
        self.layers.iter().map(|l| l.nbytes()).sum()
    }

    pub fn breakdown(&self) -> SizeBreakdown {
        self.layers
            .iter()
            .map(|l| l.breakdown())
            .fold(SizeBreakdown::default(), |acc, b| acc.add(&b))
    }

    /// Upper bound on the byte growth of one decode step across all layers
    /// (see [`LayerKv::step_growth_bound`]).
    pub fn step_growth_bound(&self) -> usize {
        self.layers.iter().map(|l| l.step_growth_bound()).sum()
    }

    /// Token count tracked by layer 0 (all layers stay in lockstep).
    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_labels() {
        assert_eq!(CacheSpec::Fp16.label(), "FP16");
        assert!(CacheSpec::gear(2).label().contains("GEAR"));
        assert!(CacheSpec::H2o { keep: 0.5, recent: 8 }.label().contains("50%"));
    }

    #[test]
    fn request_cache_builds_all_layers() {
        let rc = RequestCache::new(&CacheSpec::Fp16, 4, 32, 4);
        assert_eq!(rc.layers.len(), 4);
        assert_eq!(rc.len(), 0);
        assert!(rc.is_empty());
    }

    #[test]
    fn parse_canonical_name_round_trips() {
        // Every documented CLI form, including the 8-bit variants the old
        // doc comment omitted.
        for s in [
            "fp16",
            "gear-2", "gear-4", "gear-8",
            "gear-l-2", "gear-l-4", "gear-l-8",
            "kivi-2", "kivi-4", "kivi-8",
            "kcvt-2", "kcvt-4", "kcvt-8",
            "per-token-2", "per-token-4", "per-token-8",
            "h2o-25", "h2o-50", "h2o-100",
        ] {
            let spec = CacheSpec::parse(s).unwrap_or_else(|| panic!("{s} must parse"));
            assert_eq!(spec.canonical_name().as_deref(), Some(s), "round trip of {s}");
            // Case-insensitive parse agrees.
            assert_eq!(CacheSpec::parse(&s.to_ascii_uppercase()), Some(spec), "{s}");
        }
        // Unsupported bit widths and unknown names still rejected.
        for s in ["gear-3", "gear-l-16", "kivi-0", "bogus"] {
            assert!(CacheSpec::parse(s).is_none(), "{s}");
        }
        // Hand-built specs parse cannot express have no canonical name.
        let custom = CacheSpec::Compressed {
            method: Method::QuantOnly {
                bits: 2,
                backbone: crate::gear::compose::Backbone::Kivi(16),
            },
            buffer: 7,
            prefill_rank: 0,
            decode_rank: 0,
        };
        assert_eq!(custom.canonical_name(), None);
    }
}
