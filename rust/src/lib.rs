//! # gear-serve
//!
//! A serving framework with **GEAR KV-cache compression** as a first-class
//! feature — a Rust + JAX + Pallas reproduction of
//! *GEAR: An Efficient KV Cache Compression Recipe for Near-Lossless
//! Generative Inference of LLM* (Kang et al., 2024).
//!
//! ## Layers
//!
//! * [`gear`] — the paper's contribution: composite KV compression
//!   (`X ≈ D̂ + L + S`): ultra-low-bit quantized backbone, head-wise
//!   low-rank residual via power iteration, sparse outliers.
//! * [`kvcache`] — paged, byte-budgeted KV-cache manager with streaming
//!   buffers; stores [`gear::CompressedMatrix`] segments and answers fused
//!   attention through reusable [`kvcache::AttendScratch`] buffers.
//! * [`model`] — tiny-GPT inference (weights trained at build time by the
//!   Python layer) with pluggable KV caches; decoding runs either one
//!   request at a time or as a layer-major batched step
//!   (`Model::decode_batch`) with bit-identical results.
//! * [`coordinator`] — the serving engine, split into two planes: a
//!   deterministic FCFS *scheduler* (admission, budget, preemption) and a
//!   *batch executor* running a persistent worker pool with two layer-major
//!   entry points per sweep — a round of prefill chunks and a decode step
//!   for the whole active set — plus an asynchronous flush lane: sealed
//!   segment compressions submitted at one sweep's commit overlap the
//!   *next* sweep's prefill and decode on idle workers, and join exactly
//!   when byte accounting needs their results. Long prompts never stall
//!   the batch; compression stays off the decode critical path; token
//!   streams and peak bytes are bit-identical to sequential execution.
//!   `docs/ARCHITECTURE.md` documents the sweep phases and the full
//!   concurrency contract. The split is the scaling seam: multi-device
//!   sharding extends the executor without touching policy.
//! * [`trace`] — structured engine tracing: per-thread event rings,
//!   request lifecycle events keyed by admission serial, sweep-phase /
//!   chunk / stage / flush spans, and per-layer GEAR quality telemetry,
//!   exported as Perfetto JSON + a schema-declared JSONL journal
//!   (`GEAR_TRACE=trace.json` or `EngineConfig::with_trace`). The
//!   *logical* event stream is bit-identical across exec modes and pool
//!   sizes — a cross-plane correctness oracle on top of the token goldens.
//! * [`runtime`] — PJRT (XLA) executable loading for the AOT-compiled JAX
//!   graphs in `artifacts/` (Python never runs at serve time). Gated
//!   behind the `xla` cargo feature (needs the vendored `xla` crate).
//! * [`baselines`] — H₂O token dropping, for the paper's comparisons.
//! * [`workload`] — synthetic task generators and scorers standing in for
//!   GSM8k-CoT / LongBench (see DESIGN.md §3 for the substitution argument).
//!
//! ## Quickstart
//!
//! ```
//! use gear_serve::gear::compose::compress;
//! use gear_serve::gear::{GearConfig, KvKind, Method};
//! use gear_serve::tensor::Tensor;
//! use gear_serve::util::rng::Rng;
//!
//! let mut rng = Rng::new(0);
//! let kv = Tensor::randn(&[256, 64], &mut rng, 1.0);
//! let cfg = GearConfig::new(Method::gear_default(2), 4);
//! let c = compress(&kv, KvKind::Key, &cfg);
//! // ~2x smaller than FP16 even at this toy width (the rank-4 factors
//! // dominate at d = 64; at LLaMA widths the ratio approaches 2-bit).
//! assert!(c.kv_size_frac() < 0.5);
//! let approx = c.reconstruct();                  // near-lossless
//! assert_eq!(approx.shape(), kv.shape());
//! ```
//!
//! Engine internals — sweep phases, the scheduler/executor split, worker
//! pool lifecycle, and the asynchronous flush submit/join protocol — are
//! documented in `docs/ARCHITECTURE.md` at the repository root.

pub mod baselines;
pub mod coordinator;
pub mod gear;
pub mod kvcache;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod workload;

pub use gear::{CompressedMatrix, GearConfig, KvKind, Method};
