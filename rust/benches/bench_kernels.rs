//! Micro-benchmarks of the Rust hot paths (the §Perf measurement tool):
//! quantize, row dequantization, outlier filter, power iteration, fused
//! attention vs dense attention. Prints ns/op and effective GB/s.

use gear_serve::gear::compose::{compress, Backbone, GearConfig, Method};
use gear_serve::gear::lowrank::power_iter_lowrank;
use gear_serve::gear::outlier::filter_outliers;
use gear_serve::gear::quant::{QuantScheme, QuantizedMatrix};
use gear_serve::gear::{Axis, KvKind};
use gear_serve::tensor::Tensor;
use gear_serve::util::rng::Rng;
use gear_serve::util::table::{sig, Table};
use gear_serve::util::timing::bench_loop;
use gear_serve::workload::synth_kv::{generate, SynthKvParams};

const N: usize = 512;
const D: usize = 128;
const HEADS: usize = 4;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warmup, iters) = if quick { (2, 10) } else { (5, 40) };
    let mut rng = Rng::new(7);
    let x = generate(&mut rng, N, D, &SynthKvParams::key());
    let q: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
    let bytes = (N * D * 4) as f64;

    let mut t = Table::new(format!("Kernel micro-benchmarks ({N}x{D})").as_str())
        .header(&["op", "mean us", "p95 us", "GB/s (f32 in)"]);
    let mut row = |name: &str, mean_us: f64, p95_us: f64| {
        let gbs = bytes / (mean_us * 1e-6) / 1e9;
        t.row(vec![name.into(), sig(mean_us), sig(p95_us), sig(gbs)]);
    };

    // Quantization (2-bit KIVI).
    let s = bench_loop(warmup, iters, || {
        QuantizedMatrix::quantize(&x, 2, QuantScheme::kivi(KvKind::Key, 64))
    });
    row("quantize 2b kivi", s.mean_us(), s.p95_ns as f64 / 1e3);

    // Full-matrix dequantization.
    let qm = QuantizedMatrix::quantize(&x, 2, QuantScheme::kivi(KvKind::Key, 64));
    let mut scratch = vec![0.0f32; N * D];
    let s = bench_loop(warmup, iters, || qm.dequantize_into(&mut scratch));
    row("dequantize 2b (full)", s.mean_us(), s.p95_ns as f64 / 1e3);

    let qm4 = QuantizedMatrix::quantize(&x, 4, QuantScheme::kivi(KvKind::Key, 64));
    let s = bench_loop(warmup, iters, || qm4.dequantize_into(&mut scratch));
    row("dequantize 4b (full)", s.mean_us(), s.p95_ns as f64 / 1e3);

    // Outlier filter.
    let s = bench_loop(warmup, iters, || filter_outliers(&x, 0.02, Axis::Col));
    row("outlier filter s=2%", s.mean_us(), s.p95_ns as f64 / 1e3);

    // Power iteration (r=4, per-head block).
    let dh = D / HEADS;
    let mut head = vec![0.0f32; N * dh];
    for i in 0..N {
        head.copy_within(0..0, 0);
        head[i * dh..(i + 1) * dh].copy_from_slice(&x.row(i)[..dh]);
    }
    let s = bench_loop(warmup, iters, || {
        power_iter_lowrank(&head, N, dh, 4, 3, &mut Rng::new(1))
    });
    row("power-iter r=4 (head)", s.mean_us(), s.p95_ns as f64 / 1e3);

    // Full GEAR compression.
    let cfg = GearConfig::new(Method::gear_default(2), HEADS);
    let s = bench_loop(warmup, iters, || compress(&x, KvKind::Key, &cfg));
    row("GEAR compress (full)", s.mean_us(), s.p95_ns as f64 / 1e3);

    // Fused attention scores: compressed vs dense baseline.
    let cm = compress(&x, KvKind::Key, &cfg);
    let mut scores = vec![0.0f32; N * HEADS];
    let s = bench_loop(warmup, iters, || {
        scores.fill(0.0);
        cm.scores_into(&q, HEADS, 0.18, &mut scores);
    });
    row("fused scores (GEAR 2b)", s.mean_us(), s.p95_ns as f64 / 1e3);

    let dense = Tensor::new(&[N, D], x.data().to_vec());
    let s = bench_loop(warmup, iters, || {
        scores.fill(0.0);
        for tk in 0..N {
            for h in 0..HEADS {
                let dh = D / HEADS;
                scores[tk * HEADS + h] = gear_serve::tensor::ops::dot(
                    &q[h * dh..(h + 1) * dh],
                    &dense.row(tk)[h * dh..(h + 1) * dh],
                );
            }
        }
    });
    row("dense scores (f32)", s.mean_us(), s.p95_ns as f64 / 1e3);

    // Weighted sum.
    let probs = vec![1.0 / N as f32; N * HEADS];
    let mut ctx = vec![0.0f32; D];
    let s = bench_loop(warmup, iters, || {
        ctx.fill(0.0);
        cm.weighted_sum_into(&probs, HEADS, &mut ctx);
    });
    row("fused wsum (GEAR 2b)", s.mean_us(), s.p95_ns as f64 / 1e3);

    t.print();
    println!(
        "note: backbone variants — kcvt dequant cost vs kivi shows grouping overhead; \
         see EXPERIMENTS.md §Perf for the iteration log"
    );

    // Backbone comparison for dequant (the dominant decode cost).
    let mut t2 = Table::new("Row-dequant cost by backbone (per 512-row sweep)")
        .header(&["backbone", "mean us"]);
    for (name, scheme) in [
        ("per-token g=64", QuantScheme::per_token_group(64)),
        ("KIVI g=64 (col)", QuantScheme::kivi(KvKind::Key, 64)),
        ("KCVT (col full)", QuantScheme::kcvt(KvKind::Key)),
    ] {
        let qm = QuantizedMatrix::quantize(&x, 2, scheme);
        let mut rowbuf = vec![0.0f32; D];
        let mut plan = qm.row_plan();
        let s = bench_loop(warmup, iters, || {
            for i in 0..N {
                qm.dequantize_row_planned(i, &mut plan, &mut rowbuf);
            }
        });
        t2.row(vec![name.into(), sig(s.mean_us())]);
    }
    t2.print();
}
