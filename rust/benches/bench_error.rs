//! Approximation-error experiments: Fig 1a, Fig 2a, Fig 2b, Fig 2c.
//!
//! Run all: `cargo bench --bench bench_error`
//! One figure: `cargo bench --bench bench_error -- --fig1a`

use gear_serve::gear::compose::{compress, Backbone, GearConfig, Method};
use gear_serve::gear::error::{energy_captured, rel_error, singular_values};
use gear_serve::gear::KvKind;
use gear_serve::tensor::Tensor;
use gear_serve::util::rng::Rng;
use gear_serve::util::table::{pct, sig, Table};
use gear_serve::workload::synth_kv::{generate, SynthKvParams};

const N: usize = 512;
const D: usize = 128;
const HEADS: usize = 4;

fn kv(seed: u64, kind: KvKind) -> Tensor {
    let p = match kind {
        KvKind::Key => SynthKvParams::key(),
        KvKind::Value => SynthKvParams::value(),
    };
    generate(&mut Rng::new(seed), N, D, &p)
}

fn err_and_size(x: &Tensor, kind: KvKind, m: Method) -> (f64, f64) {
    let c = compress(x, kind, &GearConfig::new(m, HEADS));
    (rel_error(x.data(), c.reconstruct().data()), c.kv_size_frac())
}

/// Fig 1a: approximation error of methods at 2-bit compression.
fn fig1a() {
    let mut t =
        Table::new("Fig 1a — relative approximation error at 2-bit (synthetic LLaMA-like KV)")
        .header(&["method", "Key err", "Value err", "KV size"]);
    let (xk, xv) = (kv(1, KvKind::Key), kv(2, KvKind::Value));
    for m in [
        Method::QuantOnly { bits: 2, backbone: Backbone::PerTokenGroup(64) },
        Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) },
        Method::OutlierAware { bits: 2, backbone: Backbone::Kivi(64), s: 0.02 },
        Method::gear_l_default(2),
        Method::gear_default(2),
    ] {
        let (ek, _) = err_and_size(&xk, KvKind::Key, m);
        let (ev, sz) = err_and_size(&xv, KvKind::Value, m);
        t.row(vec![m.label(), sig(ek), sig(ev), pct(sz)]);
    }
    t.print();
    println!("expected shape (paper): per-token > KIVI > outlier-aware > GEAR-L > GEAR\n");
}

/// Fig 2a: single-technique error vs remaining KV size.
fn fig2a() {
    let x = kv(3, KvKind::Value);
    let mut t = Table::new("Fig 2a — single techniques cannot reach high compression")
        .header(&["technique", "config", "KV size", "rel err"]);
    for bits in [8u8, 4, 2] {
        let m = Method::QuantOnly { bits, backbone: Backbone::Kivi(64) };
        let (e, s) = err_and_size(&x, KvKind::Value, m);
        t.row(vec!["quant".into(), format!("{bits}-bit"), pct(s), sig(e)]);
    }
    for r in [64usize, 32, 16, 8, 4] {
        let (e, s) = err_and_size(&x, KvKind::Value, Method::LowRankOnly { r });
        t.row(vec!["low-rank".into(), format!("r={r}"), pct(s), sig(e)]);
    }
    for s_frac in [0.5, 0.25, 0.1, 0.05, 0.02] {
        let (e, s) = err_and_size(&x, KvKind::Value, Method::SparseOnly { s: s_frac });
        t.row(vec!["sparse".into(), format!("s={:.0}%", s_frac * 100.0), pct(s), sig(e)]);
    }
    let (e, s) = err_and_size(&x, KvKind::Value, Method::gear_default(2));
    t.row(vec!["GEAR (composite)".into(), "2-bit,s=2%,r=4".into(), pct(s), sig(e)]);
    t.print();
    println!();
}

/// Fig 2b: singular-value spectrum of the quantization residual.
fn fig2b() {
    let x = kv(4, KvKind::Value);
    let q = compress(
        &x,
        KvKind::Value,
        &GearConfig::new(Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) }, HEADS),
    );
    let recon = q.reconstruct();
    let resid: Vec<f32> = x.data().iter().zip(recon.data()).map(|(a, b)| a - b).collect();
    // Head 0's residual block, like the paper's per-head analysis.
    let dh = D / HEADS;
    let mut head0 = vec![0.0f32; N * dh];
    for i in 0..N {
        head0[i * dh..(i + 1) * dh].copy_from_slice(&resid[i * D..i * D + dh]);
    }
    let sv = singular_values(&head0, N, dh);
    let mut t = Table::new("Fig 2b — residual spectrum decays rapidly (head 0)")
        .header(&["k", "sigma_k / sigma_1", "energy captured by top-k"]);
    for k in [1usize, 2, 4, 8, 16, 32] {
        if k <= sv.len() {
            t.row(vec![
                k.to_string(),
                sig(sv[k - 1] / sv[0]),
                pct(energy_captured(&sv, k)),
            ]);
        }
    }
    t.print();
    println!();
}

/// Fig 2c: GEAR augments any off-the-shelf quantization backbone.
fn fig2c() {
    let x = kv(5, KvKind::Key);
    let mut t = Table::new("Fig 2c — GEAR improves every backbone (Key cache, 2-bit)")
        .header(&["backbone", "alone", "+GEAR-L", "+GEAR"]);
    for bb in [Backbone::PerTokenGroup(64), Backbone::Kcvt, Backbone::Kivi(64)] {
        let alone = err_and_size(&x, KvKind::Key, Method::QuantOnly { bits: 2, backbone: bb }).0;
        let gl = err_and_size(&x, KvKind::Key, Method::GearL { bits: 2, backbone: bb, r: 4 }).0;
        let g =
            err_and_size(&x, KvKind::Key, Method::Gear { bits: 2, backbone: bb, s: 0.02, r: 4 }).0;
        t.row(vec![bb.label(), sig(alone), sig(gl), sig(g)]);
    }
    t.print();
    println!();
}

/// Extension ablation (paper §6.1): adaptive per-head rank allocation vs
/// uniform, at equal total budget, on the quantization residual.
fn adaptive_ablation() {
    use gear_serve::gear::adaptive::adaptive_decompose;
    use gear_serve::gear::lowrank::HeadwiseLowRank;
    let x = kv(6, KvKind::Key);
    let q = compress(
        &x,
        KvKind::Key,
        &GearConfig::new(Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) }, HEADS),
    );
    let recon = q.reconstruct();
    let resid: Vec<f32> = x.data().iter().zip(recon.data()).map(|(a, b)| a - b).collect();
    let mut t =
        Table::new("§6.1 extension — adaptive vs uniform rank allocation on the residual")
        .header(&["total rank budget", "uniform err", "adaptive err"]);
    for total in [4usize, 8, 16, 32] {
        let uni =
            HeadwiseLowRank::decompose(&resid, N, D, HEADS, total / HEADS, 3, &mut Rng::new(8));
        let ada = adaptive_decompose(&resid, N, D, HEADS, total, 3, &mut Rng::new(8));
        let err = |hw: &HeadwiseLowRank| {
            let mut r = vec![0.0f32; N * D];
            hw.add_into(&mut r);
            let left: Vec<f32> = resid.iter().zip(&r).map(|(a, b)| a - b).collect();
            gear_serve::tensor::ops::fro_norm(&left) / gear_serve::tensor::ops::fro_norm(&resid)
        };
        t.row(vec![total.to_string(), sig(err(&uni)), sig(err(&ada))]);
    }
    t.print();
    println!();
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let want = |f: &str| {
        args.iter().any(|a| a == f)
            || !args.iter().any(|a| a.starts_with("--fig") || a.starts_with("--adaptive"))
    };
    if want("--fig1a") {
        fig1a();
    }
    if want("--fig2a") {
        fig2a();
    }
    if want("--fig2b") {
        fig2b();
    }
    if want("--fig2c") {
        fig2c();
    }
    if want("--adaptive") {
        adaptive_ablation();
    }
}
