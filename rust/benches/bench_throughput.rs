//! Efficiency experiments: Fig 3b (peak memory vs batch), Fig 3c
//! (throughput vs batch), Table 6 (detail), Table 7 (max sequence length),
//! Fig 5 (larger-memory device).
//!
//! Two complementary measurements (DESIGN.md §3):
//! 1. **Real engine runs** on the tiny model: peak cache bytes are *exact*
//!    (packed buffers), CPU wall-clock throughput is reported honestly.
//! 2. **Device-model projection** at the paper's scale (LLaMA-7B dims on a
//!    V100): byte counts from the analytic size model drive a calibrated
//!    memory-bandwidth step-time model — this is what reproduces the
//!    paper's throughput *shape* (batch scaling), which a single CPU core
//!    cannot exhibit.

use gear_serve::coordinator::device_model::DeviceModel;
use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::executor::{
    default_hybrid_threshold, default_pipeline_stages, default_pool_threads,
};
use gear_serve::coordinator::request::GenRequest;
use gear_serve::coordinator::ExecMode;
use gear_serve::gear::size::predict_cache_frac;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::util::table::{sig, Table};

/// Paper inference setting: LLaMA-7B, input 1000, generate 500, weights in
/// 8-bit (~7 GB).
const L7B_LAYERS: usize = 32;
const L7B_D: usize = 4096;
const L7B_HEADS: usize = 32;
const SEQ: usize = 1500;
const WEIGHT_BYTES: usize = 7 << 30;

fn kv_bytes_per_req(spec: &CacheSpec) -> usize {
    let fp16 = L7B_LAYERS * 2 * SEQ * L7B_D * 2;
    let frac = match spec {
        CacheSpec::Fp16 => 1.0,
        CacheSpec::Compressed { method, buffer, .. } => {
            predict_cache_frac(*method, SEQ, L7B_D, L7B_LAYERS, L7B_HEADS, *buffer)
        }
        CacheSpec::H2o { keep, .. } => *keep,
    };
    (fp16 as f64 * frac) as usize
}

fn specs() -> Vec<(&'static str, CacheSpec)> {
    vec![
        ("FP16", CacheSpec::Fp16),
        ("KIVI-2bit", CacheSpec::parse("kivi-2").unwrap()),
        ("GEAR-L-2bit", CacheSpec::gear_l(2)),
        ("GEAR-2bit", CacheSpec::gear(2)),
    ]
}

/// Fig 3b + Table 6: peak memory and projected throughput vs batch size.
fn fig3_table6(dev: &DeviceModel, title: &str) {
    let mut t = Table::new(title).header(&[
        "method",
        "batch",
        "KV GB/req",
        "total GB",
        "fits?",
        "proj tok/s",
    ]);
    for (name, spec) in specs() {
        let kv = kv_bytes_per_req(&spec);
        let max_b = dev.max_batch(WEIGHT_BYTES, kv);
        for b in [1usize, 2, 4, 8, 12, 16, 18, 24, 32] {
            let total = WEIGHT_BYTES + b * kv;
            let fits = total <= dev.capacity;
            if b > max_b && b > 1 && !fits {
                // Show the first overflowing row, then stop this method.
                t.row(vec![
                    name.into(),
                    b.to_string(),
                    sig(kv as f64 / (1 << 30) as f64),
                    sig(total as f64 / (1 << 30) as f64),
                    "OOM".into(),
                    "-".into(),
                ]);
                break;
            }
            let tput = dev.throughput(b, WEIGHT_BYTES, kv, 0);
            t.row(vec![
                name.into(),
                b.to_string(),
                sig(kv as f64 / (1 << 30) as f64),
                sig(total as f64 / (1 << 30) as f64),
                "yes".into(),
                sig(tput),
            ]);
        }
        t.row(vec![
            name.into(),
            format!("max={max_b}"),
            "-".into(),
            "-".into(),
            "-".into(),
            sig(dev.throughput(max_b.max(1), WEIGHT_BYTES, kv, 0)),
        ]);
    }
    t.print();
    println!();
}

/// Table 7: max sequence length at batch 1 within device capacity.
fn table7(dev: &DeviceModel) {
    let mut t = Table::new("Table 7 — max sequence length (batch 1, V100-16GB model)")
        .header(&["method", "bytes/token", "max length"]);
    for (name, spec) in [("FP16", CacheSpec::Fp16), ("GEAR-2bit", CacheSpec::gear(2))] {
        // Bytes per cached token at 7B scale.
        let per_tok = kv_bytes_per_req(&spec) / SEQ;
        let max_len = dev.capacity.saturating_sub(WEIGHT_BYTES) / per_tok;
        t.row(vec![name.into(), per_tok.to_string(), max_len.to_string()]);
    }
    t.print();
    println!(
        "paper: FP16 5319 vs GEAR 7291 (theirs includes activation overheads we don't model)\n"
    );
}

/// Real engine sweep on the tiny model: exact peak cache bytes + honest CPU
/// wall-clock. Single-core, so tokens/s is ~flat in batch — the projection
/// above carries the batch-scaling claim.
fn real_engine() {
    let weights = if Artifacts::available() {
        ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap()
    } else {
        eprintln!("(artifacts absent: random weights for the real-engine sweep)");
        ModelWeights::random(ModelConfig::default(), 3)
    };
    let prompt: Vec<u32> = (0..100).map(|i| (i % 46) + 3).collect();
    let mut t = Table::new("Real engine (tiny model, 1 CPU core): exact peak memory")
        .header(&["method", "batch", "peak cache MiB", "CPU tok/s", "max conc"]);
    for (name, spec) in specs() {
        for batch in [1usize, 4, 8] {
            let mut e = Engine::new(
                Model::new(weights.clone()),
                EngineConfig::new(spec).with_max_batch(batch),
            );
            for i in 0..batch {
                e.submit(GenRequest::greedy(i as u64, prompt.clone(), 50));
            }
            let _ = e.run_to_completion();
            t.row(vec![
                name.into(),
                batch.to_string(),
                sig(e.metrics.peak_cache_bytes as f64 / (1 << 20) as f64),
                sig(e.metrics.throughput()),
                e.metrics.max_concurrency.to_string(),
            ]);
        }
    }
    t.print();
    println!();
}

/// Sequential vs batched vs layer-pipelined vs hybrid decode plane, and
/// chunked vs whole-prompt prefill, on real engine runs: CPU wall-clock
/// tokens/s across `max_batch ∈ {1, 4, 16}`, plus a machine-readable
/// `BENCH_throughput.json` so the perf trajectory accumulates across PRs.
/// The hybrid leg should match or beat the better fixed plane at every
/// batch size (it picks per sweep); its per-plane sweep counters land in
/// the JSON so a miss is explainable. `smoke` shrinks the workload so CI
/// can run the comparison per push.
fn compare_exec_planes(smoke: bool) {
    let weights = if Artifacts::available() {
        ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap()
    } else {
        eprintln!("(artifacts absent: random weights for the exec-plane sweep)");
        ModelWeights::random(ModelConfig::default(), 3)
    };
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pool = default_pool_threads();
    // What a Pipelined engine resolves to with no explicit override
    // (GEAR_PIPELINE_STAGES / one stage per worker, clamped to n_layers at
    // dispatch) — recorded in the JSON so rows are interpretable offline.
    let stages_default = default_pipeline_stages(pool);
    // Likewise the hybrid plane-switch threshold a Hybrid engine resolves
    // to (GEAR_HYBRID_THRESHOLD / MIN_FANOUT).
    let hybrid_default = default_hybrid_threshold();
    // Decode-heavy workload (short prompt, long generation) and a
    // decode-only metric: prefill work is identical in both modes and would
    // otherwise dilute the comparison.
    let (prompt_len, max_new, n_reqs) =
        if smoke { (16usize, 24usize, 8usize) } else { (32, 96, 16) };
    let prompt: Vec<u32> = (0..prompt_len as u32).map(|i| (i % 46) + 3).collect();

    let mut t = Table::new(&format!(
        "Decode plane: sequential vs pooled vs pipelined vs hybrid sweep ({pool}-thread \
         pool, {host}-way host, hybrid threshold {hybrid_default}, decode-phase tok/s)"
    ))
    .header(&[
        "spec",
        "max_batch",
        "seq tok/s",
        "pool tok/s",
        "pool x",
        "pipe tok/s",
        "pipe x",
        "hybr tok/s",
        "hybr x",
        "p50 ms",
        "p99 ms",
        "flush ms",
        "overlap ms",
        "bubble ms",
    ]);
    let mut decode_rows: Vec<String> = Vec::new();

    for (name, spec) in [("fp16", CacheSpec::Fp16), ("gear-4", CacheSpec::gear(4))] {
        for batch in [1usize, 4, 16] {
            let mut tput = [0.0f64; 4];
            let mut pooled = None;
            let mut piped = None;
            let mut hybr = None;
            let mut seq_flush_ms = 0.0f64;
            for (slot, exec) in
                [ExecMode::Sequential, ExecMode::Batched, ExecMode::Pipelined, ExecMode::Hybrid]
                    .into_iter()
                    .enumerate()
            {
                let mut e = Engine::new(
                    Model::new(weights.clone()),
                    EngineConfig::new(spec).with_max_batch(batch).with_exec(exec),
                );
                for i in 0..n_reqs {
                    e.submit(GenRequest::greedy(i as u64, prompt.clone(), max_new));
                }
                let _ = e.run_to_completion();
                tput[slot] = e.metrics.decode_throughput();
                match exec {
                    // The blocking baseline: Sequential joins compress
                    // inline, so its stall is the full compression cost.
                    ExecMode::Sequential => {
                        seq_flush_ms = e.metrics.flush_stall.as_secs_f64() * 1e3;
                    }
                    ExecMode::Batched => pooled = Some(e.metrics.clone()),
                    ExecMode::Pipelined => piped = Some(e.metrics.clone()),
                    ExecMode::Hybrid => hybr = Some(e.metrics.clone()),
                }
            }
            let m = pooled.expect("batched leg always runs");
            let pm = piped.expect("pipelined leg always runs");
            let hm = hybr.expect("hybrid leg always runs");
            let speedup = tput[1] / tput[0].max(1e-9);
            let pipe_speedup = tput[2] / tput[0].max(1e-9);
            let hybrid_speedup = tput[3] / tput[0].max(1e-9);
            let (p50, p99) = (m.step_p50().as_secs_f64() * 1e3, m.step_p99().as_secs_f64() * 1e3);
            let flush_ms = m.flush_stall.as_secs_f64() * 1e3;
            let overlap_ms = m.flush_overlap_won.as_secs_f64() * 1e3;
            // Per-stage hand-off bubble (ms, stage order) over the whole
            // pipelined run; empty when the sweeps fell back to the inline
            // path (one effective stage).
            let stages = pm.stage_busy.len().max(1);
            let bubbles: Vec<String> = pm
                .stage_bubble
                .iter()
                .map(|d| format!("{:.4}", d.as_secs_f64() * 1e3))
                .collect();
            let bubble_total_ms: f64 =
                pm.stage_bubble.iter().map(|d| d.as_secs_f64() * 1e3).sum();
            t.row(vec![
                name.into(),
                batch.to_string(),
                sig(tput[0]),
                sig(tput[1]),
                format!("{speedup:.2}x"),
                sig(tput[2]),
                format!("{pipe_speedup:.2}x"),
                sig(tput[3]),
                format!("{hybrid_speedup:.2}x"),
                format!("{p50:.3}"),
                format!("{p99:.3}"),
                format!("{flush_ms:.3}"),
                format!("{overlap_ms:.3}"),
                format!("{bubble_total_ms:.3}"),
            ]);
            decode_rows.push(format!(
                "{{\"spec\": \"{name}\", \"max_batch\": {batch}, \
                 \"seq_decode_tok_s\": {:.3}, \"batched_decode_tok_s\": {:.3}, \
                 \"speedup\": {speedup:.4}, \"pipelined_decode_tok_s\": {:.3}, \
                 \"pipeline_speedup\": {pipe_speedup:.4}, \"pipeline_stages\": {stages}, \
                 \"stage_bubble_ms\": [{}], \"step_p50_ms\": {p50:.4}, \
                 \"step_p99_ms\": {p99:.4}, \"flush_jobs\": {}, \
                 \"flush_stall_ms\": {flush_ms:.4}, \
                 \"seq_flush_stall_ms\": {seq_flush_ms:.4}, \
                 \"flush_overlap_won_ms\": {overlap_ms:.4}, \
                 \"hybrid_decode_tok_s\": {:.3}, \"hybrid_speedup\": {hybrid_speedup:.4}, \
                 \"hybrid_batched_sweeps\": {}, \"hybrid_pipelined_sweeps\": {}, \
                 \"hybrid_switches\": {}, \"hybrid_batched_tok_s\": {:.3}, \
                 \"hybrid_pipelined_tok_s\": {:.3}}}",
                tput[0],
                tput[1],
                tput[2],
                bubbles.join(", "),
                m.flush_jobs,
                tput[3],
                hm.hybrid_batched_sweeps,
                hm.hybrid_pipelined_sweeps,
                hm.hybrid_switches,
                hm.hybrid_batched_throughput(),
                hm.hybrid_pipelined_throughput()
            ));
        }
    }
    t.print();
    println!(
        "expected shape: pool ~1x at batch 1 (inline path), > 1x at batch >= 8 on \
         multi-core; pipe > 1x already at batch 1 (layer stages overlap within one \
         request) with the win bounded by the deepest stage; hybr >= max(pool, pipe) \
         at every batch — it pipelines below the threshold and chunks above it \
         (per-plane sweep counters are in the JSON if it misses); flush ms is the \
         residual join stall after overlapping with the next sweep \
         (seq_flush_stall_ms in the JSON is the blocking baseline it beat; overlap \
         ms is compression wall time hidden off the critical path; bubble ms sums \
         each stage's upstream hand-off wait — per-stage values are in the JSON)\n"
    );

    // Chunked vs whole-prompt prefill on a prompt-heavy workload: total
    // tokens/s (prefill included). Chunking must not regress throughput;
    // its win is latency (decode keeps flowing while long prompts prefill),
    // which run_to_completion totals cannot show.
    let (long_len, pre_new, pre_reqs) =
        if smoke { (96usize, 12usize, 6usize) } else { (192, 24, 12) };
    let long_prompt: Vec<u32> = (0..long_len as u32).map(|i| (i % 46) + 3).collect();
    let mut t = Table::new(&format!(
        "Prefill plane: whole-prompt vs chunked ({long_len}-token prompts, total tok/s)"
    ))
    .header(&["spec", "max_batch", "whole tok/s", "chunked tok/s", "ratio"]);
    let mut prefill_rows: Vec<String> = Vec::new();
    for (name, spec) in [("fp16", CacheSpec::Fp16), ("gear-4", CacheSpec::gear(4))] {
        for batch in [1usize, 4, 16] {
            let mut tput = [0.0f64; 2];
            for (slot, chunk) in [usize::MAX, 32].into_iter().enumerate() {
                let mut e = Engine::new(
                    Model::new(weights.clone()),
                    EngineConfig::new(spec).with_max_batch(batch).with_prefill_chunk(chunk),
                );
                for i in 0..pre_reqs {
                    e.submit(GenRequest::greedy(i as u64, long_prompt.clone(), pre_new));
                }
                let _ = e.run_to_completion();
                tput[slot] = e.metrics.throughput();
            }
            let ratio = tput[1] / tput[0].max(1e-9);
            t.row(vec![
                name.into(),
                batch.to_string(),
                sig(tput[0]),
                sig(tput[1]),
                format!("{ratio:.2}x"),
            ]);
            prefill_rows.push(format!(
                "{{\"spec\": \"{name}\", \"max_batch\": {batch}, \
                 \"whole_prefill_tok_s\": {:.3}, \"chunked_prefill_tok_s\": {:.3}, \
                 \"ratio\": {ratio:.4}}}",
                tput[0], tput[1]
            ));
        }
    }
    t.print();
    println!("expected shape: ratio ~1x (chunking is a latency feature, not a throughput one)\n");

    // `schema` lists the per-row keys explicitly so CI can diff the shape of
    // a regenerated file against the committed seed even when the seed's row
    // arrays are empty (see "provenance" in the committed file).
    let json = format!(
        "{{\n  \"bench\": \"throughput_compare\",\n  \"provenance\": \"measured\",\n  \
         \"schema\": {{\n    \"decode_plane_row\": [\"spec\", \"max_batch\", \
         \"seq_decode_tok_s\", \"batched_decode_tok_s\", \"speedup\", \
         \"pipelined_decode_tok_s\", \"pipeline_speedup\", \"pipeline_stages\", \
         \"stage_bubble_ms\", \"step_p50_ms\", \
         \"step_p99_ms\", \"flush_jobs\", \"flush_stall_ms\", \"seq_flush_stall_ms\", \
         \"flush_overlap_won_ms\", \"hybrid_decode_tok_s\", \"hybrid_speedup\", \
         \"hybrid_batched_sweeps\", \"hybrid_pipelined_sweeps\", \"hybrid_switches\", \
         \"hybrid_batched_tok_s\", \"hybrid_pipelined_tok_s\"],\n    \
         \"chunked_prefill_row\": [\"spec\", \"max_batch\", \
         \"whole_prefill_tok_s\", \"chunked_prefill_tok_s\", \"ratio\"]\n  }},\n  \
         \"mode\": \"{}\",\n  \"host_parallelism\": {host},\n  \"pool_threads\": {pool},\n  \
         \"pipeline_stages_default\": {stages_default},\n  \
         \"hybrid_threshold_default\": {hybrid_default},\n  \
         \"decode_workload\": {{\"prompt_len\": {prompt_len}, \
         \"max_new_tokens\": {max_new}, \"requests\": {n_reqs}}},\n  \
         \"prefill_workload\": {{\"prompt_len\": {long_len}, \
         \"max_new_tokens\": {pre_new}, \"requests\": {pre_reqs}, \
         \"prefill_chunk\": 32}},\n  \
         \"decode_plane\": [\n    {}\n  ],\n  \"chunked_prefill\": [\n    {}\n  ]\n}}\n",
        if smoke { "smoke" } else { "full" },
        decode_rows.join(",\n    "),
        prefill_rows.join(",\n    ")
    );
    let path = "BENCH_throughput.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let all = !args.iter().any(|a| {
        a.starts_with("--fig") || a.starts_with("--table") || a == "--real" || a == "--compare"
    });
    let want = |f: &str| all || args.iter().any(|a| a == f);
    let smoke = args.iter().any(|a| a == "--smoke");
    let v100 = DeviceModel::v100();
    if want("--fig3b") || want("--fig3c") {
        fig3_table6(&v100, "Fig 3b/3c + Table 6 — V100-16GB projection (LLaMA-7B scale)");
    }
    if want("--table7") {
        table7(&v100);
    }
    if want("--fig5") {
        fig3_table6(&DeviceModel::rtx_titan(), "Fig 5 — RTX-Titan-24GB projection");
    }
    if want("--real") {
        real_engine();
    }
    if want("--compare") {
        compare_exec_planes(smoke);
    }
}
