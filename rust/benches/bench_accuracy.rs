//! Generative-accuracy experiments over the trained model:
//! Table 1 (hard CoT tasks), Table 2 (easy tasks), Table 8 (outlier-aware),
//! Table 10 (H₂O), Fig 4a (s/r ablation), Fig 4c (accuracy vs ratio).
//!
//! Requires `make artifacts` (trained checkpoint). Flags: `--table1`,
//! `--table2`, `--table8`, `--table10`, `--fig4a`, `--fig4c`, `--quick`
//! (fewer instances), `--n <count>`.

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::GenRequest;
use gear_serve::gear::compose::{Backbone, Method};
use gear_serve::gear::size::predict_cache_frac;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::Tokenizer;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::util::table::{pct, Table};
use gear_serve::workload::tasks::{self, Task, TaskInstance};

fn load() -> Option<ModelWeights> {
    if !Artifacts::available() {
        eprintln!("bench_accuracy: artifacts not built (run `make artifacts`); skipping");
        return None;
    }
    Some(ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap())
}

fn accuracy(weights: &ModelWeights, spec: &CacheSpec, set: &[TaskInstance]) -> f64 {
    let tok = Tokenizer::new();
    let mut e = Engine::new(Model::new(weights.clone()), EngineConfig::new(*spec));
    for (i, inst) in set.iter().enumerate() {
        e.submit(
            GenRequest::greedy(i as u64, tok.encode_with_bos(&inst.prompt), 56)
                .with_newline_stop(),
        );
    }
    let results = e.run_to_completion();
    let correct = results
        .iter()
        .filter(|r| tasks::score(&r.text(), &set[r.id as usize]))
        .count();
    correct as f64 / set.len() as f64
}

/// KV-size % at paper scale (LLaMA-7B dims, prefill 900 + 256 generated).
fn paper_scale_size(spec: &CacheSpec) -> f64 {
    match spec {
        CacheSpec::Fp16 => 1.0,
        CacheSpec::Compressed { method, buffer, .. } => {
            predict_cache_frac(*method, 1156, 4096, 32, 32, *buffer)
        }
        CacheSpec::H2o { keep, .. } => *keep,
    }
}

fn method_rows(bits: u8) -> Vec<(String, CacheSpec)> {
    let quant = |m: Method, b: usize| CacheSpec::quant(m, b);
    let mut rows = vec![
        ("FP16".to_string(), CacheSpec::Fp16),
        (
            format!("Per-token Q g=64 ({bits}b)"),
            quant(Method::QuantOnly { bits, backbone: Backbone::PerTokenGroup(64) }, 64),
        ),
        (
            format!("KIVI g=64 ({bits}b)"),
            quant(Method::QuantOnly { bits, backbone: Backbone::Kivi(64) }, 64),
        ),
    ];
    if bits == 4 {
        rows.push((
            "KCVT (4b)".to_string(),
            quant(Method::QuantOnly { bits: 4, backbone: Backbone::Kcvt }, 20),
        ));
    }
    rows.push((format!("GEAR-L ({bits}b)"), CacheSpec::gear_l(bits)));
    rows.push((format!("GEAR ({bits}b)"), CacheSpec::gear(bits)));
    rows
}

fn table(title: &str, weights: &ModelWeights, set: &[TaskInstance], bits_list: &[u8]) {
    let mut t = Table::new(title).header(&["method", "bits", "KV size (7B-scale)", "accuracy"]);
    for &bits in bits_list {
        for (name, spec) in method_rows(bits) {
            if bits != bits_list[0] && name == "FP16" {
                continue;
            }
            let acc = accuracy(weights, &spec, set);
            let b = if name == "FP16" { 16 } else { bits };
            t.row(vec![name, b.to_string(), pct(paper_scale_size(&spec)), pct(acc)]);
        }
    }
    t.print();
    println!();
}

fn table1(weights: &ModelWeights, n: usize) {
    let set = tasks::generate_set(Task::ChainArith { steps: 4, shots: 2 }, n, 42);
    table("Table 1 — hard CoT task (chain-arith), 4-bit and 2-bit", weights, &set, &[4, 2]);
    println!("expected shape (paper): at 2-bit, quant-only collapses; GEAR(-L) near FP16\n");
}

fn table2(weights: &ModelWeights, n: usize) {
    let set = tasks::generate_set(Task::KvRecall { pairs: 20 }, n, 43);
    table("Table 2 — easy task (kv-recall): compression-insensitive", weights, &set, &[4, 2]);
}

fn table8(weights: &ModelWeights, n: usize) {
    let set = tasks::generate_set(Task::ChainArith { steps: 4, shots: 2 }, n, 44);
    let bb = Backbone::Kivi(64);
    let mut t = Table::new("Table 8 — outlier-aware quant alone is not enough (2-bit)")
        .header(&["method", "accuracy"]);
    for (name, spec) in [
        ("FP16".to_string(), CacheSpec::Fp16),
        (
            "KIVI 2-bit".to_string(),
            CacheSpec::quant(Method::QuantOnly { bits: 2, backbone: bb }, 64),
        ),
        (
            "Outlier-Aware (s=2%) 2-bit".to_string(),
            CacheSpec::quant(Method::OutlierAware { bits: 2, backbone: bb, s: 0.02 }, 64),
        ),
        ("GEAR-L 2-bit".to_string(), CacheSpec::gear_l(2)),
        ("GEAR 2-bit".to_string(), CacheSpec::gear(2)),
    ] {
        t.row(vec![name, pct(accuracy(weights, &spec, &set))]);
    }
    t.print();
    println!();
}

fn table10(weights: &ModelWeights, n: usize) {
    let set = tasks::generate_set(Task::ChainArith { steps: 4, shots: 2 }, n, 45);
    let mut t = Table::new("Table 10 — token dropping (H2O) fails on reasoning tasks")
        .header(&["method", "KV size", "accuracy"]);
    for (name, spec, size) in [
        ("FP16", CacheSpec::Fp16, 1.0),
        ("H2O keep=50%", CacheSpec::H2o { keep: 0.5, recent: 16 }, 0.5),
        ("GEAR 4-bit", CacheSpec::gear(4), paper_scale_size(&CacheSpec::gear(4))),
    ] {
        t.row(vec![name.to_string(), pct(size), pct(accuracy(weights, &spec, &set))]);
    }
    t.print();
    println!();
}

fn fig4a(weights: &ModelWeights, n: usize) {
    let set = tasks::generate_set(Task::ChainArith { steps: 4, shots: 2 }, n, 46);
    let bb = Backbone::Kivi(64);
    let mut t = Table::new("Fig 4a — ablation on sparsity s and rank r (2-bit)")
        .header(&["s", "r", "accuracy"]);
    for (s, r) in [(0.0, 0), (0.02, 0), (0.0, 4), (0.02, 2), (0.02, 4), (0.04, 4), (0.02, 8)] {
        let method = match (s > 0.0, r > 0) {
            (false, false) => Method::QuantOnly { bits: 2, backbone: bb },
            (true, false) => Method::OutlierAware { bits: 2, backbone: bb, s },
            (false, true) => Method::GearL { bits: 2, backbone: bb, r },
            (true, true) => Method::Gear { bits: 2, backbone: bb, s, r },
        };
        let spec = CacheSpec::Compressed {
            method,
            buffer: 20,
            prefill_rank: r,
            decode_rank: r.min(2),
        };
        t.row(vec![
            format!("{:.0}%", s * 100.0),
            r.to_string(),
            pct(accuracy(weights, &spec, &set)),
        ]);
    }
    t.print();
    println!("expected shape (paper): r=0 rows collapse; small s,r already near-lossless\n");
}

fn fig4c(weights: &ModelWeights, n: usize) {
    let set = tasks::generate_set(Task::ChainArith { steps: 4, shots: 2 }, n, 47);
    let mut t = Table::new("Fig 4c — accuracy vs compression ratio")
        .header(&["method", "bits", "KV size (7B-scale)", "accuracy"]);
    for bits in [8u8, 4, 2] {
        for (name, spec) in [
            (
                format!("KIVI {bits}b"),
                CacheSpec::quant(
                    Method::QuantOnly { bits, backbone: Backbone::Kivi(64) },
                    64,
                ),
            ),
            (format!("GEAR-L {bits}b"), CacheSpec::gear_l(bits)),
            (format!("GEAR {bits}b"), CacheSpec::gear(bits)),
        ] {
            t.row(vec![
                name,
                bits.to_string(),
                pct(paper_scale_size(&spec)),
                pct(accuracy(weights, &spec, &set)),
            ]);
        }
    }
    t.print();
    println!();
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let Some(weights) = load() else { return };
    let quick = args.iter().any(|a| a == "--quick");
    let n = args
        .iter()
        .position(|a| a == "--n")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 8 } else { 14 });
    let all = !args.iter().any(|a| a.starts_with("--table") || a.starts_with("--fig"));
    let want = |f: &str| all || args.iter().any(|a| a == f);

    if want("--table1") {
        table1(&weights, n);
    }
    if want("--table2") {
        table2(&weights, n);
    }
    if want("--table8") {
        table8(&weights, n);
    }
    if want("--table10") {
        table10(&weights, n);
    }
    if want("--fig4a") {
        fig4a(&weights, n);
    }
    if want("--fig4c") {
        fig4c(&weights, n);
    }
}
