//! Component breakdowns: Fig 3a (wall-clock time per GEAR component),
//! Table 9 (KV size per method × dataset), Fig 6 (cache memory components).

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::GenRequest;
use gear_serve::gear::compose::{Backbone, Method};
use gear_serve::gear::size::{predict, SizeBreakdown};
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::ModelConfig;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::util::table::{pct, sig, Table};

fn weights() -> ModelWeights {
    if Artifacts::available() {
        ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap()
    } else {
        eprintln!("(artifacts absent: random weights)");
        ModelWeights::random(ModelConfig::default(), 3)
    }
}

/// Fig 3a: wall-time share of quant / low-rank / sparse vs model forward.
fn fig3a() {
    let w = weights();
    let prompt: Vec<u32> = (0..120).map(|i| (i % 46) + 3).collect();
    let mut t = Table::new("Fig 3a — wall-clock time breakdown during generation")
        .header(&["method", "quant", "lowrank", "sparse", "other (fwd)"]);
    for (name, spec) in [
        ("GEAR-2bit", CacheSpec::gear(2)),
        ("GEAR-L-2bit", CacheSpec::gear_l(2)),
        ("KIVI-2bit", CacheSpec::parse("kivi-2").unwrap()),
    ] {
        let mut e = Engine::new(Model::new(w.clone()), EngineConfig::new(spec));
        for i in 0..4u64 {
            e.submit(GenRequest::greedy(i, prompt.clone(), 60));
        }
        let _ = e.run_to_completion();
        let rows = e.metrics.time_breakdown();
        t.row(vec![
            name.into(),
            pct(rows[0].2),
            pct(rows[1].2),
            pct(rows[2].2),
            pct(rows[3].2),
        ]);
    }
    t.print();
    println!("expected shape (paper): forward dominates; sparse+lowrank are small\n");
}

/// Table 9: per-dataset average KV size at the paper's scale.
fn table9() {
    // Paper's dataset statistics (prefill, generation) — Appendix Table 3.
    let datasets = [
        ("GSM8k-CoT", 900usize, 256usize),
        ("AQuA-CoT", 1304, 196),
        ("BBH-CoT", 1021, 196),
        ("LongBench", 3642, 256),
    ];
    let methods: Vec<(String, Method, usize)> = vec![
        (
            "Per-token Q 4b".into(),
            Method::QuantOnly { bits: 4, backbone: Backbone::PerTokenGroup(64) },
            64,
        ),
        ("KCVT 4b".into(), Method::QuantOnly { bits: 4, backbone: Backbone::Kcvt }, 20),
        ("KIVI 4b".into(), Method::QuantOnly { bits: 4, backbone: Backbone::Kivi(64) }, 64),
        ("GEAR-L 4b".into(), Method::gear_l_default(4), 20),
        ("GEAR 4b".into(), Method::gear_default(4), 20),
        (
            "Per-token Q 2b".into(),
            Method::QuantOnly { bits: 2, backbone: Backbone::PerTokenGroup(64) },
            64,
        ),
        ("KIVI 2b".into(), Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) }, 64),
        ("GEAR-L 2b".into(), Method::gear_l_default(2), 20),
        ("GEAR 2b".into(), Method::gear_default(2), 20),
    ];
    let mut t = Table::new("Table 9 — average KV size per dataset (LLaMA-7B scale)").header(&[
        "method", "GSM8k", "AQuA", "BBH", "LongBench",
    ]);
    for (name, m, buffer) in methods {
        let mut cells = vec![name];
        for (_, prefill, gen) in datasets {
            let n = prefill + gen;
            let frac = gear_serve::gear::size::predict_cache_frac(m, n, 4096, 32, 32, buffer);
            cells.push(pct(frac));
        }
        t.row(cells);
    }
    t.print();
    println!();
}

/// Fig 6: cache memory distribution by component (real engine run).
fn fig6() {
    let w = weights();
    let prompt: Vec<u32> = (0..120).map(|i| (i % 46) + 3).collect();
    let mut t = Table::new("Fig 6 — KV cache memory distribution by component (measured)")
        .header(&["method", "quant", "scale/zero", "sparse", "lowrank", "buffer(FP16)"]);
    for (name, spec) in [
        ("KCVT-4bit", CacheSpec::parse("kcvt-4").unwrap()),
        ("KIVI-2bit", CacheSpec::parse("kivi-2").unwrap()),
        ("GEAR-L-2bit", CacheSpec::gear_l(2)),
        ("GEAR-2bit", CacheSpec::gear(2)),
    ] {
        // Build one request cache mid-generation and inspect it.
        let c = w.config;
        let mut cache =
            gear_serve::kvcache::RequestCache::new(&spec, c.n_layers, c.d_model, c.n_heads);
        let model = Model::new(w.clone());
        model.prefill(&prompt, &mut cache);
        for step in 0..30 {
            model.decode_step(5, prompt.len() + step, &mut cache);
        }
        let bd: SizeBreakdown = cache.breakdown();
        let total = bd.total().max(1) as f64;
        t.row(vec![
            name.into(),
            pct(bd.quant_bytes as f64 / total),
            pct(bd.meta_bytes as f64 / total),
            pct(bd.sparse_bytes as f64 / total),
            pct(bd.lowrank_bytes as f64 / total),
            pct(bd.dense_bytes as f64 / total),
        ]);
    }
    t.print();
    println!("paper's observation: KIVI pays in scale/zero + residual buffer; KCVT does not\n");

    // Analytic cross-check at 7B scale.
    let mut t2 = Table::new("Fig 6 (analytic, LLaMA-7B scale, n=1156)")
        .header(&["method", "quant", "scale/zero", "sparse", "lowrank"]);
    for (name, m) in [
        ("KIVI 2b", Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) }),
        ("GEAR 2b", Method::gear_default(2)),
    ] {
        let b = predict(m, true, 1156, 4096, 32);
        let total = b.total().max(1) as f64;
        t2.row(vec![
            name.into(),
            pct(b.quant_bytes as f64 / total),
            pct(b.meta_bytes as f64 / total),
            pct(b.sparse_bytes as f64 / total),
            pct(b.lowrank_bytes as f64 / total),
        ]);
    }
    t2.print();
    println!();
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let all = !args.iter().any(|a| a.starts_with("--fig") || a.starts_with("--table"));
    let want = |f: &str| all || args.iter().any(|a| a == f);
    if want("--fig3a") {
        fig3a();
    }
    if want("--table9") {
        table9();
    }
    if want("--fig6") {
        fig6();
    }
}
