//! Fig 4b + method sweep: how much of the cache gets error reduction
//! matters, and GEAR wins across compression ratios (error-level view).
//!
//! ```bash
//! cargo run --release --example compare_methods
//! ```

use gear_serve::gear::compose::{compress, Backbone, GearConfig, Method};
use gear_serve::gear::error::rel_error;
use gear_serve::gear::KvKind;
use gear_serve::util::rng::Rng;
use gear_serve::util::table::{pct, sig, Table};
use gear_serve::workload::synth_kv::{generate, SynthKvParams};

fn main() {
    let mut rng = Rng::new(1);
    let (n, d, heads) = (512usize, 128usize, 4usize);
    let x = generate(&mut rng, n, d, &SynthKvParams::key());

    // --- Fig 4b: apply low-rank error reduction to only the most recent
    // p% of prefill tokens. Older tokens stay quant-only.
    let mut t = Table::new("Fig 4b — error reduction applied to p% most recent tokens")
        .header(&["p", "rel err (whole cache)"]);
    let quant_only = Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) };
    let gear_l = Method::gear_l_default(2);
    for p in [1.0f64, 0.75, 0.5, 0.25, 0.0] {
        let split = n - (n as f64 * p) as usize;
        // Old segment: quant only. Recent segment: GEAR-L.
        let old = x.slice_rows(0, split);
        let recent = x.slice_rows(split, n);
        let mut recon = Vec::with_capacity(n * d);
        if split > 0 {
            let c = compress(&old, KvKind::Key, &GearConfig::new(quant_only, heads));
            recon.extend_from_slice(c.reconstruct().data());
        }
        if split < n {
            let c = compress(&recent, KvKind::Key, &GearConfig::new(gear_l, heads));
            recon.extend_from_slice(c.reconstruct().data());
        }
        t.row(vec![pct(p), sig(rel_error(x.data(), &recon))]);
    }
    t.print();
    println!("expected shape (paper Fig 4b): error grows as p shrinks\n");

    // --- Accuracy-free ratio sweep (Fig 4c error-level companion).
    let mut t2 = Table::new("Method sweep — error vs size across ratios")
        .header(&["method", "KV size", "rel err"]);
    for m in [
        Method::QuantOnly { bits: 8, backbone: Backbone::Kivi(64) },
        Method::QuantOnly { bits: 4, backbone: Backbone::Kivi(64) },
        Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) },
        Method::gear_l_default(4),
        Method::gear_l_default(2),
        Method::gear_default(4),
        Method::gear_default(2),
    ] {
        let c = compress(&x, KvKind::Key, &GearConfig::new(m, heads));
        t2.row(vec![
            m.label(),
            pct(c.kv_size_frac()),
            sig(rel_error(x.data(), c.reconstruct().data())),
        ]);
    }
    t2.print();

    // Value-cache regime too (flatter channels).
    let xv = generate(&mut rng, n, d, &SynthKvParams::value());
    let mut t3 = Table::new("Same sweep on the Value-cache regime")
        .header(&["method", "KV size", "rel err"]);
    for m in [
        Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) },
        Method::gear_l_default(2),
        Method::gear_default(2),
    ] {
        let c = compress(&xv, KvKind::Value, &GearConfig::new(m, heads));
        t3.row(vec![
            m.label(),
            pct(c.kv_size_frac()),
            sig(rel_error(xv.data(), c.reconstruct().data())),
        ]);
    }
    t3.print();
}
