//! Fig 1b reproduction: logit deviation from the FP16 baseline compounds
//! across autoregressive decoding steps.
//!
//! Decodes the same prompt greedily under the FP16 cache (reference) and
//! under each compressed cache, *forcing the reference token path* so that
//! per-step logit distances are comparable, then prints the per-step L2
//! deviation — the error-compounding picture that motivates GEAR.
//!
//! ```bash
//! cargo run --release --example error_analysis
//! ```

use gear_serve::kvcache::{CacheSpec, RequestCache};
use gear_serve::model::config::Tokenizer;
use gear_serve::model::sampler::argmax;
use gear_serve::model::{Model, ModelConfig, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::tensor::ops::fro_dist;
use gear_serve::util::table::{sig, Table};
use gear_serve::workload::tasks::{self, Task};

fn main() {
    let weights = if Artifacts::available() {
        ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap()
    } else {
        eprintln!("(artifacts absent: random weights — deviation shapes still hold)");
        ModelWeights::random(ModelConfig::default(), 3)
    };
    let model = Model::new(weights);
    let c = *model.config();
    let tok = Tokenizer::new();
    let inst = tasks::generate_set(Task::ChainArith { steps: 5, shots: 2 }, 1, 9).remove(0);
    let prompt = tok.encode_with_bos(&inst.prompt);
    let steps = 32usize;

    // Reference FP16 trajectory (greedy tokens + per-step logits).
    let mut ref_cache = RequestCache::new(&CacheSpec::Fp16, c.n_layers, c.d_model, c.n_heads);
    let mut ref_logits = Vec::with_capacity(steps);
    let mut ref_tokens = Vec::with_capacity(steps);
    let mut logits = model.prefill(&prompt, &mut ref_cache).last_logits;
    for s in 0..steps {
        let t = argmax(&logits);
        ref_tokens.push(t);
        logits = model.decode_step(t, prompt.len() + s, &mut ref_cache);
        ref_logits.push(logits.clone());
    }

    let specs = [
        ("per-token-2", CacheSpec::parse("per-token-2").unwrap()),
        ("KIVI-2", CacheSpec::parse("kivi-2").unwrap()),
        ("GEAR-L-2", CacheSpec::gear_l(2)),
        ("GEAR-2", CacheSpec::gear(2)),
    ];

    let mut table = Table::new("Fig 1b — per-step logit L2 deviation from FP16 (teacher-forced)")
        .header(&["step", specs[0].0, specs[1].0, specs[2].0, specs[3].0]);

    let mut deviations: Vec<Vec<f64>> = Vec::new();
    for (_, spec) in &specs {
        let mut cache = RequestCache::new(spec, c.n_layers, c.d_model, c.n_heads);
        let _ = model.prefill(&prompt, &mut cache);
        let mut devs = Vec::with_capacity(steps);
        for s in 0..steps {
            let logits = model.decode_step(ref_tokens[s], prompt.len() + s, &mut cache);
            devs.push(fro_dist(&logits, &ref_logits[s]));
        }
        deviations.push(devs);
    }

    for s in (0..steps).step_by(4) {
        table.row(vec![
            s.to_string(),
            sig(deviations[0][s]),
            sig(deviations[1][s]),
            sig(deviations[2][s]),
            sig(deviations[3][s]),
        ]);
    }
    table.print();

    let grow = |d: &Vec<f64>| d.last().unwrap() / d.first().unwrap().max(1e-9);
    println!("\ndeviation growth (last/first step):");
    for ((name, _), d) in specs.iter().zip(&deviations) {
        println!("  {name:<14} {:.2}x", grow(d));
    }
    println!("\nexpected shape (paper Fig 1b): plain quantization deviations grow with");
    println!("step index and dwarf GEAR's, which stays near the FP16 trajectory.");
}
