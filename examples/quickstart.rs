//! Quickstart: compress a KV matrix with GEAR and inspect error vs size.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gear_serve::gear::compose::{compress, Backbone, GearConfig, Method};
use gear_serve::gear::error::rel_error;
use gear_serve::gear::KvKind;
use gear_serve::util::rng::Rng;
use gear_serve::util::table::{pct, sig, Table};
use gear_serve::workload::synth_kv::{generate, SynthKvParams};

fn main() {
    // A Key-cache-like matrix: 512 tokens x 128 channels with the
    // heavy-tailed fixed channels the paper analyzes.
    let mut rng = Rng::new(0);
    let kv = generate(&mut rng, 512, 128, &SynthKvParams::key());

    let mut table = Table::new("GEAR quickstart: compress 512x128 Key cache")
        .header(&["method", "KV size vs FP16", "relative error"]);

    for method in [
        Method::Fp16,
        Method::QuantOnly { bits: 2, backbone: Backbone::Kivi(64) },
        Method::gear_l_default(2),
        Method::gear_default(2),
    ] {
        let cfg = GearConfig::new(method, 4);
        let compressed = compress(&kv, KvKind::Key, &cfg);
        let recon = compressed.reconstruct();
        table.row(vec![
            method.label(),
            pct(compressed.kv_size_frac()),
            sig(rel_error(kv.data(), recon.data())),
        ]);
    }
    table.print();

    println!("\nThe GEAR rows keep ~4x compression while cutting the 2-bit");
    println!("quantization error by an order of magnitude — the paper's core claim.");
}
