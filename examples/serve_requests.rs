//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Loads the build-time-trained model, serves a batch of chain-arith (hard,
//! CoT) and kv-recall (easy) requests through the continuous-batching engine
//! under several KV-cache compression policies, and reports accuracy,
//! latency, throughput, and peak cache memory — all layers composed:
//! trained weights (L2 build path) → Rust engine + GEAR cache (L3) →
//! optionally the XLA decode path (runtime).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_requests
//! ```

use gear_serve::coordinator::engine::{Engine, EngineConfig};
use gear_serve::coordinator::request::GenRequest;
use gear_serve::kvcache::CacheSpec;
use gear_serve::model::config::Tokenizer;
use gear_serve::model::{Model, ModelWeights};
use gear_serve::runtime::artifacts::Artifacts;
use gear_serve::util::table::{pct, sig, Table};
use gear_serve::workload::tasks::{self, Task};

fn main() {
    if !Artifacts::available() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let weights = ModelWeights::load(&Artifacts::default_dir().join("weights.bin")).unwrap();
    let tok = Tokenizer::new();
    let n = 24;

    for (task_name, task) in [
        ("chain-arith (hard, CoT)", Task::ChainArith { steps: 4, shots: 2 }),
        ("kv-recall (easy)", Task::KvRecall { pairs: 16 }),
    ] {
        let set = tasks::generate_set(task, n, 7);
        let mut table = Table::new(&format!("serve_requests — {task_name}, {n} requests"))
            .header(&["cache", "accuracy", "tok/s", "peak cache KiB", "preempt"]);
        for spec in [
            CacheSpec::Fp16,
            CacheSpec::parse("kivi-2").unwrap(),
            CacheSpec::gear_l(2),
            CacheSpec::gear(2),
            CacheSpec::gear(4),
        ] {
            let mut engine = Engine::new(Model::new(weights.clone()), EngineConfig::new(spec));
            for (i, inst) in set.iter().enumerate() {
                engine.submit(
                    GenRequest::greedy(i as u64, tok.encode_with_bos(&inst.prompt), 56)
                        .with_newline_stop(),
                );
            }
            let results = engine.run_to_completion();
            let correct = results
                .iter()
                .filter(|r| tasks::score(&r.text(), &set[r.id as usize]))
                .count();
            table.row(vec![
                spec.label(),
                pct(correct as f64 / n as f64),
                sig(engine.metrics.throughput()),
                sig(engine.metrics.peak_cache_bytes as f64 / 1024.0),
                engine.metrics.requests_preempted.to_string(),
            ]);
        }
        table.print();
        println!();
    }

    // A traced run: same engine, tracing on. Writes a Perfetto-loadable
    // JSON (open at https://ui.perfetto.dev) plus the JSONL journal next
    // to it, and folds the TraceSummary into the metrics — the same
    // numbers the server's `metrics` verb reports as trace_* lines.
    // `GEAR_TRACE=trace.json` does the same without touching code.
    {
        let trace_path = std::env::temp_dir().join("serve_requests_trace.json");
        let cfg = EngineConfig::new(CacheSpec::gear(4)).with_trace(&trace_path);
        let mut engine = Engine::new(Model::new(weights.clone()), cfg);
        let set = tasks::generate_set(Task::KvRecall { pairs: 16 }, 8, 7);
        for (i, inst) in set.iter().enumerate() {
            engine.submit(
                GenRequest::greedy(i as u64, tok.encode_with_bos(&inst.prompt), 56)
                    .with_newline_stop(),
            );
        }
        engine.run_to_completion();
        if let Some(t) = engine.metrics.trace {
            println!(
                "traced run: {} events ({} logical), {} quality records, \
                 {} B actual vs {} B predicted, max ‖X−X̂‖_F {:.4}",
                t.events,
                t.logical_events,
                t.quality_records,
                t.bytes_actual,
                t.bytes_predicted,
                t.max_err_fro
            );
            println!("trace written: {} (+ .jsonl journal)", trace_path.display());
        }
        println!();
    }

    // One request through the XLA (AOT) backend to prove the full
    // three-layer path: JAX-authored -> HLO text -> PJRT in Rust.
    #[cfg(feature = "xla")]
    match gear_serve::runtime::xla_model::XlaModel::load_default() {
        Ok(xm) => {
            let inst = tasks::generate_set(Task::KvRecall { pairs: 8 }, 1, 3).remove(0);
            let nl = tok.encode("\n")[0];
            let out = xm
                .generate_greedy(
                    &tok.encode_with_bos(&inst.prompt),
                    24,
                    &[gear_serve::model::config::EOS, nl],
                )
                .unwrap();
            println!("XLA backend: prompt {:?}", inst.prompt.trim_end());
            println!(
                "XLA backend: generated {:?} (expected answer {})",
                tok.decode(&out),
                inst.answer
            );
        }
        Err(e) => println!("XLA backend unavailable: {e}"),
    }
    #[cfg(not(feature = "xla"))]
    println!("XLA backend: skipped (build with --features xla to exercise the PJRT path)");
}
